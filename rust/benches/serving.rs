//! Serving benches, two parts:
//!
//! 1. Dispatch cost: planned micro-batch rounds (`PredictService::serve`
//!    over group pre-assignment) vs ad-hoc per-request jobs. Acceptance:
//!    planned dispatch is >=2x cheaper on driver dispatch cost.
//! 2. SLO serving under a straggler: `Batching::Adaptive` vs the best
//!    fixed batch when one node pays a per-round delay. Acceptance:
//!    adaptive holds p99 <= SLO and <= the fixed path's p99, at >= 0.8x
//!    the fixed path's throughput; `Replication::Auto` re-replicates the
//!    hot shard within 2 dispatch cycles. All gated in CI from the
//!    recorded series.
//!
//! Runs entirely on closure models — no AOT artifacts needed.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use bigdl::bigdl::serving::{BatchScorer, PredictService, Reduction};
use bigdl::bigdl::serving_strategy::ServingStrategy;
use bigdl::sparklet::SparkletContext;
use bigdl::util::prng::Rng;

fn linear_scorer(dim: usize, classes: usize) -> BatchScorer<Vec<f32>> {
    Arc::new(move |w: &Arc<Vec<f32>>, items: &[Vec<f32>]| {
        Ok(items
            .iter()
            .map(|x| {
                (0..classes)
                    .map(|c| x.iter().zip(&w[c * dim..(c + 1) * dim]).map(|(a, b)| a * b).sum())
                    .collect()
            })
            .collect())
    })
}

/// Scorer that spins `per_item` of wall clock per scored item — a
/// deterministic stand-in for real model compute, so round latency scales
/// with batch size the way the adaptive controller assumes.
fn spinning_scorer(dim: usize, classes: usize, per_item: Duration) -> BatchScorer<Vec<f32>> {
    let inner = linear_scorer(dim, classes);
    Arc::new(move |w: &Arc<Vec<f32>>, items: &[Vec<f32>]| {
        let deadline = Instant::now() + per_item * items.len() as u32;
        let rows = inner(w, items)?;
        while Instant::now() < deadline {
            std::hint::spin_loop();
        }
        Ok(rows)
    })
}

fn random_requests(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..dim).map(|_| rng.gen_f32() - 0.5).collect())
        .collect()
}

fn main() {
    let mut rec = common::Recorder::new("serving");
    dispatch_bench(&mut rec);
    slo_bench(&mut rec);
    hot_reshard_bench(&mut rec);
    rec.flush();
}

fn dispatch_bench(rec: &mut common::Recorder) {
    common::banner(
        "Serving: planned (run_rounds) vs ad-hoc per-request dispatch",
        "group-planned serving amortizes driver dispatch >=2x at identical predictions",
    );

    let nodes = 8;
    let (dim, classes) = (32, 10);
    let n_requests = common::iters(4096, 1024);
    let max_batch = 64;
    let reps = common::iters(5, 2);

    let ctx = SparkletContext::local(nodes);
    let svc = PredictService::new(
        &ctx,
        linear_scorer(dim, classes),
        ServingStrategy::default()
            .fixed_batch(max_batch)
            .group(n_requests / max_batch),
    )
    .expect("service");
    let mut rng = Rng::new(0x5E11E);
    let weights: Vec<f32> = (0..dim * classes).map(|_| rng.gen_f32() - 0.5).collect();
    svc.deploy(&weights).expect("deploy");
    let requests = random_requests(&mut rng, n_requests, dim);

    // Warm-up both paths (thread pools, allocator).
    let planned_out = svc.serve(&requests, Reduction::Argmax).expect("planned serve");
    let adhoc_out = svc.serve_adhoc(&requests, Reduction::Argmax).expect("ad-hoc serve");
    let identical = planned_out == adhoc_out;

    let measure = |planned: bool| -> (f64, f64, u64) {
        let s0 = ctx.scheduler().stats.snapshot();
        let t0 = Instant::now();
        for _ in 0..reps {
            let out = if planned {
                svc.serve(&requests, Reduction::Argmax)
            } else {
                svc.serve_adhoc(&requests, Reduction::Argmax)
            }
            .expect("serve");
            assert_eq!(out.len(), n_requests);
        }
        let wall = t0.elapsed().as_secs_f64();
        let s1 = ctx.scheduler().stats.snapshot();
        let per_req_dispatch =
            (s1.dispatch_ns - s0.dispatch_ns) as f64 / (reps * n_requests) as f64 / 1e9;
        let per_req_wall = wall / (reps * n_requests) as f64;
        (per_req_dispatch, per_req_wall, s1.placements - s0.placements)
    };

    let (adhoc_disp, adhoc_wall, adhoc_place) = measure(false);
    let (planned_disp, planned_wall, planned_place) = measure(true);
    let ratio = adhoc_disp / planned_disp.max(1e-12);

    println!(
        "workload: {n_requests} requests/call x {reps} calls, {max_batch}/round, {nodes} nodes\n\
         identical predictions (planned vs ad-hoc): {identical}\n\
         {:>24} {:>14} {:>14} {:>12}\n\
         {:>24} {:>11.3} ns {:>11.3} us {:>12}\n\
         {:>24} {:>11.3} ns {:>11.3} us {:>12}\n\
         driver dispatch ratio:   {ratio:.2}x lower with planned rounds (target >= 2x)",
        "", "dispatch/req", "wall/req", "placements",
        "ad-hoc per-request:", adhoc_disp * 1e9, adhoc_wall * 1e6, adhoc_place,
        "planned (run_rounds):", planned_disp * 1e9, planned_wall * 1e6, planned_place,
    );
    if !identical {
        println!("  WARNING: planned and ad-hoc predictions diverged");
    }
    if ratio < 2.0 {
        println!("  WARNING: planned-dispatch speedup below the 2x acceptance target");
    }
    let params = [
        ("nodes", nodes as f64),
        ("requests", n_requests as f64),
        ("max_batch", max_batch as f64),
        ("reps", reps as f64),
    ];
    rec.add("adhoc_dispatch_per_req_ns", &params, adhoc_disp * 1e9, "ns");
    rec.add("planned_dispatch_per_req_ns", &params, planned_disp * 1e9, "ns");
    rec.add("planned_dispatch_ratio", &params, ratio, "x");
}

/// Straggler sim: one node pays a fixed per-round delay, compute scales
/// with batch size. The adaptive controller must find a batch whose round
/// latency sits inside the SLO band — under the fixed comparator's p99 —
/// while keeping throughput within 20% of the large fixed batch.
fn slo_bench(rec: &mut common::Recorder) {
    common::banner(
        "SLO serving: adaptive batching vs best fixed batch under a straggler",
        "adaptive holds p99 <= SLO at >= 0.8x the fixed path's throughput",
    );

    let nodes = 4;
    let (dim, classes) = (16, 8);
    let slo_ms = 10.0;
    let (min_batch, max_batch) = (64, 1024);
    let straggle = Duration::from_millis(2);
    // ~31us/item: a full 1024 batch costs ~8ms of compute across 4 nodes
    // — over the SLO once the 2ms straggler delay is added, so the
    // controller must settle below the fixed comparator's batch.
    let per_item = Duration::from_micros(31);
    let n = common::iters(4096, 2048);

    let ctx = SparkletContext::local(nodes);
    let mut rng = Rng::new(0x51013);
    let weights: Vec<f32> = (0..dim * classes).map(|_| rng.gen_f32() - 0.5).collect();
    let requests = random_requests(&mut rng, n, dim);

    let fixed = PredictService::new(
        &ctx,
        spinning_scorer(dim, classes, per_item),
        ServingStrategy::default().fixed_batch(max_batch),
    )
    .expect("fixed service");
    let adaptive = PredictService::new(
        &ctx,
        spinning_scorer(dim, classes, per_item),
        ServingStrategy::default().adaptive(slo_ms, min_batch, max_batch),
    )
    .expect("adaptive service");
    fixed.deploy(&weights).expect("deploy");
    adaptive.deploy(&weights).expect("deploy");
    fixed.inject_node_delay(0, straggle);
    adaptive.inject_node_delay(0, straggle);

    // Warm-up: let the controller climb out of its min batch (and both
    // paths fault in their thread pools) before measuring.
    let f_out = fixed.serve(&requests, Reduction::Argmax).expect("fixed warm-up");
    let a_out = adaptive.serve(&requests, Reduction::Argmax).expect("adaptive warm-up");
    assert_eq!(f_out, a_out, "adaptive batching must not change predictions");

    let t0 = Instant::now();
    fixed.serve(&requests, Reduction::Argmax).expect("fixed serve");
    let fixed_wall = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    adaptive.serve(&requests, Reduction::Argmax).expect("adaptive serve");
    let adaptive_wall = t1.elapsed().as_secs_f64();

    let f = fixed.stats.snapshot();
    let a = adaptive.stats.snapshot();
    let p99_ratio = a.p99_ms / f.p99_ms.max(1e-9);
    let tput_ratio = (n as f64 / adaptive_wall) / (n as f64 / fixed_wall).max(1e-9);

    println!(
        "workload: {n} requests, {nodes} nodes, {straggle:?} straggler on node 0, \
         ~{}us/item compute\n\
         {:>22} {:>10} {:>10} {:>12} {:>12}\n\
         {:>22} {:>10.2} {:>10.2} {:>12} {:>12.0}\n\
         {:>22} {:>10.2} {:>10.2} {:>12} {:>12.0}\n\
         adaptive vs fixed: p99 {p99_ratio:.2}x (target <= 1.0), \
         throughput {tput_ratio:.2}x (target >= 0.8)",
        per_item.as_micros(),
        "", "p50 ms", "p99 ms", "final batch", "req/s",
        format!("fixed({max_batch}):"), f.p50_ms, f.p99_ms, max_batch,
        n as f64 / fixed_wall,
        format!("adaptive(slo {slo_ms}):"), a.p50_ms, a.p99_ms, adaptive.batch_size(),
        n as f64 / adaptive_wall,
    );
    if a.p99_ms > slo_ms {
        println!("  WARNING: adaptive p99 {:.2}ms exceeds the {slo_ms}ms SLO", a.p99_ms);
    }
    if p99_ratio > 1.0 {
        println!("  WARNING: adaptive p99 above the fixed comparator's");
    }
    if tput_ratio < 0.8 {
        println!("  WARNING: adaptive throughput below 0.8x fixed");
    }

    let params = [
        ("nodes", nodes as f64),
        ("requests", n as f64),
        ("slo_ms", slo_ms),
        ("min_batch", min_batch as f64),
        ("max_batch", max_batch as f64),
    ];
    rec.add("serving_p50_ms", &params, a.p50_ms, "ms");
    rec.add("serving_p99_ms", &params, a.p99_ms, "ms");
    rec.add("fixed_p99_ms", &params, f.p99_ms, "ms");
    rec.add("adaptive_vs_fixed_p99_ratio", &params, p99_ratio, "x");
    rec.add("adaptive_vs_fixed_throughput_ratio", &params, tput_ratio, "x");
}

/// Hot-shard autoscale: with `Replication::Auto`, a sustained straggler
/// on one shard's owner must trigger a re-replication within 2 dispatch
/// cycles (the policy's sustain window).
fn hot_reshard_bench(rec: &mut common::Recorder) {
    common::banner(
        "Autoscale: hot-shard re-replication latency",
        "a sustained hot shard re-replicates within 2 dispatch cycles",
    );

    let nodes = 4;
    let (dim, classes) = (16, 8);
    let ctx = SparkletContext::local(nodes);
    let svc = PredictService::new(
        &ctx,
        linear_scorer(dim, classes),
        ServingStrategy::default().fixed_batch(64).auto_scale(1.8),
    )
    .expect("service");
    let mut rng = Rng::new(0x407B);
    let weights: Vec<f32> = (0..dim * classes).map(|_| rng.gen_f32() - 0.5).collect();
    svc.deploy(&weights).expect("deploy");
    let requests = random_requests(&mut rng, 64, dim);
    svc.serve(&requests, Reduction::Argmax).expect("warm-up");

    let hot_owner = svc.shard_owners()[0];
    svc.inject_node_delay(hot_owner, Duration::from_millis(5));
    let mut cycles = 0u64;
    while cycles < 6 && svc.stats.snapshot().re_replications == 0 {
        svc.serve(&requests, Reduction::Argmax).expect("serve");
        cycles += 1;
    }
    let fired = svc.stats.snapshot().re_replications > 0;
    println!(
        "hot shard 0 (owner node {hot_owner}): re-replication after {cycles} dispatch \
         cycles (target <= 2, fired: {fired})"
    );
    if !fired || cycles > 2 {
        println!("  WARNING: hot-shard re-replication missed the 2-cycle target");
    }
    let params = [("nodes", nodes as f64), ("hot_watermark", 1.8)];
    rec.add("hot_reshard_cycles", &params, cycles as f64, "cycles");
}

//! Shared bench harness helpers (criterion is unavailable offline; these
//! benches are `harness = false` binaries that print the paper's
//! tables/series in a fixed format captured into bench_output.txt).
#![allow(dead_code)] // each bench binary uses a subset of these helpers

use bigdl::runtime::{default_artifacts_dir, RuntimeHandle};

/// Standard bench banner.
pub fn banner(fig: &str, claim: &str) {
    println!("\n================================================================");
    println!("{fig}");
    println!("paper claim: {claim}");
    println!("================================================================");
}

/// Load the runtime or exit 0 with a SKIP notice (benches must not fail
/// the suite when artifacts haven't been built).
pub fn runtime_or_skip() -> Option<RuntimeHandle> {
    let dir = default_artifacts_dir();
    if !dir.join("ncf.meta.json").exists() {
        println!("SKIP: artifacts missing at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(RuntimeHandle::load(&dir).expect("loading artifacts"))
}

/// Measure the Sparklet driver's per-task dispatch cost with PER-ITERATION
/// scheduling (place + enqueue every task, every job) — used to calibrate
/// the Fig 8 model with a *measured* number.
pub fn measure_dispatch_cost(nodes: usize, tasks: usize, reps: usize) -> f64 {
    use std::sync::Arc;
    let ctx = bigdl::sparklet::SparkletContext::local(nodes);
    let preferred: Vec<Option<usize>> = (0..tasks).map(|p| Some(p % nodes)).collect();
    // Warm-up.
    ctx.run_job(&preferred, Arc::new(|_tc| Ok(()))).unwrap();
    let before = ctx.scheduler().stats.snapshot();
    for _ in 0..reps {
        ctx.run_job(&preferred, Arc::new(|_tc| Ok(()))).unwrap();
    }
    let after = ctx.scheduler().stats.snapshot();
    let launched = (after.tasks_launched - before.tasks_launched) as f64;
    (after.dispatch_ns - before.dispatch_ns) as f64 / launched / 1e9
}

/// Measure the per-task dispatch cost with Drizzle GROUP PRE-ASSIGNMENT:
/// placements planned once, every job dispatched as bare batched enqueues
/// (one channel send per node) through the JobRunner.
pub fn measure_dispatch_cost_planned(nodes: usize, tasks: usize, reps: usize) -> f64 {
    use bigdl::sparklet::TaskContext;
    use std::sync::Arc;
    let ctx = bigdl::sparklet::SparkletContext::local(nodes);
    let runner = ctx.runner();
    let preferred: Vec<Option<usize>> = (0..tasks).map(|p| Some(p % nodes)).collect();
    let plan = runner.plan_group(&preferred).unwrap();
    let noop: Arc<dyn Fn(&TaskContext) -> anyhow::Result<()> + Send + Sync> =
        Arc::new(|_tc| Ok(()));
    // Warm-up.
    runner.run_planned(&plan, Arc::clone(&noop)).unwrap();
    let before = ctx.scheduler().stats.snapshot();
    for _ in 0..reps {
        runner.run_planned(&plan, Arc::clone(&noop)).unwrap();
    }
    let after = ctx.scheduler().stats.snapshot();
    let launched = (after.tasks_launched - before.tasks_launched) as f64;
    (after.dispatch_ns - before.dispatch_ns) as f64 / launched / 1e9
}

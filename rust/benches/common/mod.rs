//! Shared bench harness helpers (criterion is unavailable offline; these
//! benches are `harness = false` binaries that print the paper's
//! tables/series in a fixed format captured into bench_output.txt).
//!
//! Two CI hooks:
//! * **quick mode** ([`quick`] / [`iters`]) — `BENCH_QUICK=1` (or a
//!   `--quick` argv flag) shrinks iteration counts so the whole suite
//!   runs in seconds; CI's `bench-smoke` job uses it on every PR;
//! * **result recording** ([`Recorder`]) — when `BENCH_JSONL` names a
//!   file, each recorded series is appended as one JSON object per line
//!   (the repo's `BENCH_*.json` schema is these records wrapped in
//!   `{"schema":"bigdl-bench/v1","results":[...]}` — CI assembles
//!   `BENCH_CI.json` with `jq -s` and uploads it as the perf-trajectory
//!   artifact).
#![allow(dead_code)] // each bench binary uses a subset of these helpers

use std::io::Write;

use bigdl::runtime::{default_artifacts_dir, RuntimeHandle};

/// Standard bench banner.
pub fn banner(fig: &str, claim: &str) {
    println!("\n================================================================");
    println!("{fig}");
    println!("paper claim: {claim}");
    println!("================================================================");
}

/// Quick mode: `BENCH_QUICK=1` env (CI bench-smoke) or a `--quick` flag.
pub fn quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0")
        || std::env::args().any(|a| a == "--quick")
}

/// Pick an iteration count: `full` normally, `quick_n` under quick mode.
pub fn iters(full: usize, quick_n: usize) -> usize {
    if quick() {
        quick_n
    } else {
        full
    }
}

/// Appends bench results as JSON Lines to the file named by `BENCH_JSONL`
/// (no-op when unset). One record per series:
/// `{"bench":..,"series":..,"params":{..},"value":..,"unit":..}`.
pub struct Recorder {
    bench: &'static str,
    lines: Vec<String>,
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

impl Recorder {
    pub fn new(bench: &'static str) -> Recorder {
        Recorder { bench, lines: Vec::new() }
    }

    /// Record one scalar result. `params` are (name, value) pairs
    /// describing the configuration the value was measured under.
    pub fn add(&mut self, series: &str, params: &[(&str, f64)], value: f64, unit: &str) {
        let params_json: Vec<String> = params
            .iter()
            .map(|(k, v)| format!("\"{}\":{}", json_escape(k), fmt_f64(*v)))
            .collect();
        self.lines.push(format!(
            "{{\"bench\":\"{}\",\"series\":\"{}\",\"params\":{{{}}},\"value\":{},\"unit\":\"{}\",\"quick\":{}}}",
            json_escape(self.bench),
            json_escape(series),
            params_json.join(","),
            fmt_f64(value),
            json_escape(unit),
            quick(),
        ));
    }

    /// Append every recorded line to `$BENCH_JSONL` (if set). Call once at
    /// the end of the bench's `main`.
    pub fn flush(&mut self) {
        let Ok(path) = std::env::var("BENCH_JSONL") else { return };
        if path.is_empty() || self.lines.is_empty() {
            return;
        }
        let mut f = match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("BENCH_JSONL: cannot open {path}: {e}");
                return;
            }
        };
        for l in self.lines.drain(..) {
            let _ = writeln!(f, "{l}");
        }
    }
}

/// f64 → JSON number (finite; NaN/inf become null).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Load the runtime or exit 0 with a SKIP notice (benches must not fail
/// the suite when artifacts haven't been built).
pub fn runtime_or_skip() -> Option<RuntimeHandle> {
    let dir = default_artifacts_dir();
    if !dir.join("ncf.meta.json").exists() {
        println!("SKIP: artifacts missing at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(RuntimeHandle::load(&dir).expect("loading artifacts"))
}

/// Measure the Sparklet driver's per-task dispatch cost with PER-ITERATION
/// scheduling (place + enqueue every task, every job) — used to calibrate
/// the Fig 8 model with a *measured* number.
pub fn measure_dispatch_cost(nodes: usize, tasks: usize, reps: usize) -> f64 {
    use std::sync::Arc;
    let ctx = bigdl::sparklet::SparkletContext::local(nodes);
    let preferred: Vec<Option<usize>> = (0..tasks).map(|p| Some(p % nodes)).collect();
    // Warm-up.
    ctx.run_job(&preferred, Arc::new(|_tc| Ok(()))).unwrap();
    let before = ctx.scheduler().stats.snapshot();
    for _ in 0..reps {
        ctx.run_job(&preferred, Arc::new(|_tc| Ok(()))).unwrap();
    }
    let after = ctx.scheduler().stats.snapshot();
    let launched = (after.tasks_launched - before.tasks_launched) as f64;
    (after.dispatch_ns - before.dispatch_ns) as f64 / launched / 1e9
}

/// Measure the per-task dispatch cost with Drizzle GROUP PRE-ASSIGNMENT:
/// placements planned once, every job dispatched as bare batched enqueues
/// (one channel send per node) through the JobRunner.
pub fn measure_dispatch_cost_planned(nodes: usize, tasks: usize, reps: usize) -> f64 {
    use bigdl::sparklet::TaskContext;
    use std::sync::Arc;
    let ctx = bigdl::sparklet::SparkletContext::local(nodes);
    let runner = ctx.runner();
    let preferred: Vec<Option<usize>> = (0..tasks).map(|p| Some(p % nodes)).collect();
    let plan = runner.plan_group(&preferred).unwrap();
    let noop: Arc<dyn Fn(&TaskContext) -> anyhow::Result<()> + Send + Sync> =
        Arc::new(|_tc| Ok(()));
    // Warm-up.
    runner.run_planned(&plan, Arc::clone(&noop)).unwrap();
    let before = ctx.scheduler().stats.snapshot();
    for _ in 0..reps {
        runner.run_planned(&plan, Arc::clone(&noop)).unwrap();
    }
    let after = ctx.scheduler().stats.snapshot();
    let launched = (after.tasks_launched - before.tasks_launched) as f64;
    (after.dispatch_ns - before.dispatch_ns) as f64 / launched / 1e9
}

//! Ablations (DESIGN.md E7/E8 + §3.4 discussion):
//!  1. AllReduce traffic: Algorithm 2 (measured through the block store)
//!     vs Ring AllReduce vs centralized PS (executable references).
//!  2. Failure recovery: fine-grained task re-run vs gang restart, under
//!     injected failures, measured as extra tasks run and wall time.
//!  3. Drizzle pre-assignment: driver dispatch cost per task with and
//!     without group pre-planning (real scheduler measurement).

mod common;

use std::sync::Arc;

use bigdl::bigdl::allreduce::{central_ps_reduce, ring_allreduce, traffic, SyncAlgo};
use bigdl::bigdl::{DistributedOptimizer, Module, Sgd, TrainConfig};
use bigdl::data::movielens::{movielens_rdd, MovielensConfig};
use bigdl::sparklet::{FailurePolicy, SchedulePolicy, SparkletContext};
use bigdl::util::prng::Rng;

fn ablation_allreduce() {
    common::banner(
        "Ablation E7: per-node sync traffic — Alg 2 vs Ring vs central PS",
        "Alg 2 ≈ 2K per node flat in N; Ring same bytes, Θ(N) steps; PS hot node N·K",
    );
    let k = common::iters(100_000, 20_000); // parameters (400 KB full mode)
    println!(
        "{:>6} {:>22} {:>22} {:>22}",
        "N", "shuffle-bcast out/node", "ring out/node (meas.)", "PS server in (meas.)"
    );
    for n in [4, 8, 16, 32] {
        let model = traffic(SyncAlgo::ShuffleBroadcast, n, (k * 4) as f64);
        let mut rng = Rng::new(n as u64);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..k).map(|_| rng.gen_f32()).collect())
            .collect();
        let (ring_sum, ring_traffic) = ring_allreduce(&grads);
        let (ps_sum, ps_traffic) = central_ps_reduce(&grads);
        // Correctness cross-check: both must equal the naive sum.
        let mut naive = vec![0.0f32; k];
        for g in &grads {
            bigdl::tensor::add_assign(&mut naive, g);
        }
        let ring_err = ring_sum
            .iter()
            .zip(&naive)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(ring_err < 1e-2, "ring mismatch {ring_err}");
        assert_eq!(ps_sum, naive);
        println!(
            "{:>6} {:>20.0}KB {:>20.0}KB {:>20.0}KB",
            n,
            model.out_bytes / 1024.0,
            ring_traffic[0].0 as f64 / 1024.0,
            ps_traffic[0].1 as f64 / 1024.0,
        );
    }
    println!(
        "steps/round: shuffle-bcast = 2; ring(32) = {}; PS = 2",
        traffic(SyncAlgo::Ring, 32, 1.0).steps
    );
}

fn ablation_failure_recovery() {
    common::banner(
        "Ablation E8: failure recovery — fine-grained re-run vs gang restart",
        "stateless short tasks → re-run only what failed (§3.4)",
    );
    let Some(rt) = common::runtime_or_skip() else { return };
    let module = Module::load(&rt, "ncf").unwrap();
    let iters = common::iters(6, 3);
    let mut run = |gang: bool, fail_prob: f64| -> (f64, u64, u64, u64) {
        let ctx = SparkletContext::local(4);
        ctx.set_schedule_policy(SchedulePolicy { gang, ..Default::default() });
        ctx.set_failure_policy(FailurePolicy {
            task_fail_prob: fail_prob,
            max_attempts: 20,
            max_job_restarts: 200,
            seed: 99,
            ..Default::default()
        });
        let data = movielens_rdd(&ctx, MovielensConfig::default(), 4, 300, 3);
        let mut opt = DistributedOptimizer::new(
            &ctx,
            module.clone(),
            data,
            Arc::new(Sgd::new(0.01)),
            TrainConfig { iterations: iters, log_every: 0, ..Default::default() },
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        opt.optimize().unwrap();
        let s = ctx.scheduler().stats.snapshot();
        (t0.elapsed().as_secs_f64(), s.tasks_launched, s.task_retries, s.gang_restarts)
    };

    println!("{:>24} {:>10} {:>10} {:>10} {:>10}", "mode", "wall(s)", "tasks", "retries", "restarts");
    let (t, tasks, _, _) = run(false, 0.0);
    println!("{:>24} {:>10.2} {:>10} {:>10} {:>10}", "baseline (no failures)", t, tasks, 0, 0);
    let (t, tasks, retries, _) = run(false, 0.10);
    println!("{:>24} {:>10.2} {:>10} {:>10} {:>10}", "fine-grained, p=0.10", t, tasks, retries, 0);
    let (t, tasks, _, restarts) = run(true, 0.10);
    println!("{:>24} {:>10.2} {:>10} {:>10} {:>10}", "gang (connector), p=0.10", t, tasks, 0, restarts);
    println!("\nshape check: gang re-runs whole jobs → strictly more tasks + wall time.");
    rt.shutdown();
}

fn ablation_drizzle_dispatch() {
    common::banner(
        "Ablation: Drizzle pre-assignment — measured driver dispatch/task",
        "group pre-planning removes per-iteration placement work (§4.4)",
    );
    let nodes = 8;
    let tasks = 256;
    let reps = common::iters(30, 5);
    let ctx = SparkletContext::local(nodes);
    let preferred: Vec<Option<usize>> = (0..tasks).map(|p| Some(p % nodes)).collect();
    let noop: Arc<dyn Fn(&bigdl::sparklet::TaskContext) -> anyhow::Result<()> + Send + Sync> =
        Arc::new(|_tc| Ok(()));

    ctx.run_job(&preferred, Arc::clone(&noop)).unwrap(); // warm-up
    let b0 = ctx.scheduler().stats.snapshot();
    for _ in 0..reps {
        ctx.run_job(&preferred, Arc::clone(&noop)).unwrap();
    }
    let b1 = ctx.scheduler().stats.snapshot();
    let per_task = (b1.dispatch_ns - b0.dispatch_ns) as f64
        / (b1.tasks_launched - b0.tasks_launched) as f64;

    let policy = ctx.schedule_policy();
    let plan = ctx.scheduler().plan(&ctx.cluster(), &preferred, &policy).unwrap();
    let c0 = ctx.scheduler().stats.snapshot();
    for _ in 0..reps {
        ctx.run_job_preassigned(&preferred, &plan, Arc::clone(&noop)).unwrap();
    }
    let c1 = ctx.scheduler().stats.snapshot();
    let per_task_planned = (c1.dispatch_ns - c0.dispatch_ns) as f64
        / (c1.tasks_launched - c0.tasks_launched) as f64;

    println!("per-task dispatch: default {:.1}µs  pre-assigned {:.1}µs  ({:.2}x)",
        per_task / 1e3,
        per_task_planned / 1e3,
        per_task / per_task_planned.max(1.0)
    );
    println!("(in-process lower bound; a real Spark driver adds ms-scale RPC per task — Fig 8)");
}

fn main() {
    ablation_allreduce();
    ablation_failure_recovery();
    ablation_drizzle_dispatch();
}

//! Sharded predict-serving walkthrough: deploy a model into a
//! `PredictService` (sharded + replicated weight blocks), serve
//! micro-batched requests through planned `run_rounds` dispatch, survive a
//! node death mid-stream, and compare the driver dispatch cost against
//! ad-hoc per-request jobs. Runs on a closure model — no AOT artifacts
//! needed.
//!
//!   cargo run --release --example predict_serving

use std::sync::Arc;

use anyhow::Result;

use bigdl::bigdl::serving::{BatchScorer, PredictService, Reduced, Reduction, ServingConfig};
use bigdl::sparklet::SparkletContext;
use bigdl::util::prng::Rng;

fn main() -> Result<()> {
    bigdl::util::logging::init();
    let nodes = 4;
    let (dim, classes) = (16, 4);
    let ctx = SparkletContext::local(nodes);

    // The "model": a linear scorer (full weights + request batch -> rows).
    let scorer: BatchScorer<Vec<f32>> = Arc::new(move |w: &Arc<Vec<f32>>, items: &[Vec<f32>]| {
        Ok(items
            .iter()
            .map(|x| {
                (0..classes)
                    .map(|c| x.iter().zip(&w[c * dim..(c + 1) * dim]).map(|(a, b)| a * b).sum())
                    .collect()
            })
            .collect())
    });

    // Deploy: weights shard across nodes (one owner per node + a replica).
    let service = PredictService::new(
        &ctx,
        scorer,
        ServingConfig { max_batch: 64, group_size: 32, ..Default::default() },
    );
    let mut rng = Rng::new(42);
    let weights: Vec<f32> = (0..dim * classes).map(|_| rng.gen_f32() - 0.5).collect();
    service.deploy(&weights)?;

    // Serve: micro-batched rounds through one group plan; argmax runs
    // task-side, so only (class, score) rows reach the driver.
    let requests: Vec<Vec<f32>> = (0..2048)
        .map(|_| (0..dim).map(|_| rng.gen_f32() - 0.5).collect())
        .collect();
    let s0 = ctx.scheduler().stats.snapshot();
    let planned = service.serve(&requests, Reduction::Argmax)?;
    let s1 = ctx.scheduler().stats.snapshot();
    let adhoc = service.serve_adhoc(&requests, Reduction::Argmax)?;
    let s2 = ctx.scheduler().stats.snapshot();
    anyhow::ensure!(planned == adhoc, "planned and ad-hoc dispatch must agree");
    println!(
        "served {} requests: planned placements {} vs ad-hoc {} (dispatch {:.1}us vs {:.1}us)",
        requests.len(),
        s1.placements - s0.placements,
        s2.placements - s1.placements,
        (s1.dispatch_ns - s0.dispatch_ns) as f64 / 1e3,
        (s2.dispatch_ns - s1.dispatch_ns) as f64 / 1e3,
    );

    // Kill a node mid-stream: replicated shards + mid-group replanning
    // keep serving exact.
    ctx.cluster().kill_node(1);
    ctx.blocks().kill_node(1);
    let after = service.serve(&requests, Reduction::Argmax)?;
    anyhow::ensure!(planned == after, "predictions must survive node death");
    let mut queue_depth = vec![0usize; classes];
    for p in &after {
        if let Reduced::Class { class, .. } = p {
            queue_depth[*class] += 1;
        }
    }
    println!(
        "after killing node 1: predictions identical; class queue depths {queue_depth:?}; \
         serving stats {:?}",
        service.stats.snapshot()
    );
    println!("predict_serving OK");
    Ok(())
}

//! Pipelined (bounded-staleness) training on the builtin LinReg model —
//! runs everywhere, no AOT artifacts needed.
//!
//! Sync mode pays two barriers per iteration (forward-backward, then the
//! parameter sync). `SyncMode::Pipelined { staleness: s }` dispatches
//! BOTH jobs asynchronously — the forward-backward through
//! `Rdd::submit_partition_job` and the sync through
//! `ParameterManager::sync_round_async`, `JobHandle`s over the engine's
//! CompletionHub — so up to `s` gradient rounds are genuinely in flight
//! at once: iteration k's forward overlapping iteration k+1's forward
//! AND the in-flight sync (watch `max fwd jobs in flight` below).
//!
//!     cargo run --release --example pipelined_training

use std::sync::Arc;
use std::time::{Duration, Instant};

use bigdl::bigdl::builtin::{linreg_rdd, ComputeSim, LinReg, SimOptim};
use bigdl::bigdl::{DistributedOptimizer, Module, Sgd, SyncMode, TrainConfig};
use bigdl::sparklet::SparkletContext;

fn run(mode: SyncMode) -> anyhow::Result<()> {
    let nodes = 4;
    let rounds = 20;
    let base = Duration::from_micros(1500);
    let straggle = Duration::from_millis(6);
    let ctx = SparkletContext::local(nodes);
    // Simulated heterogeneous cluster: a rotating straggler on the
    // forward-backward AND on the shard update.
    let model = LinReg::new(1024, 16).with_compute(ComputeSim::new(base, straggle, nodes));
    let module = Module::builtin(Arc::new(model));
    let data = linreg_rdd(&ctx, 1024, nodes, 64, 42);
    let optim = Arc::new(SimOptim::new(Arc::new(Sgd::new(0.05)), base, straggle, nodes));
    let mut opt = DistributedOptimizer::new(
        &ctx,
        module,
        data,
        optim,
        TrainConfig { iterations: rounds, log_every: 0, sync_mode: mode, ..Default::default() },
    )?;
    let t0 = Instant::now();
    let report = opt.optimize()?;
    let max_lag = opt.history.iter().map(|m| m.sync_lag).max().unwrap_or(0);
    let max_overlap = opt.history.iter().map(|m| m.fwd_overlap).max().unwrap_or(1);
    println!(
        "{mode:?}: {:.0} ms wall, {:.1} ms/iter, final loss {:.4}, max weight-read lag \
         {max_lag}, max fwd jobs in flight {max_overlap}",
        t0.elapsed().as_secs_f64() * 1e3,
        t0.elapsed().as_secs_f64() * 1e3 / rounds as f64,
        report.final_loss,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    run(SyncMode::Sync)?;
    run(SyncMode::Pipelined { staleness: 1 })?;
    run(SyncMode::Pipelined { staleness: 2 })?;
    Ok(())
}

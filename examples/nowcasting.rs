//! §5.2 (Cray) precipitation nowcasting: read radar scans → train a
//! ConvLSTM Seq2Seq model → predict the next frames — one unified Spark
//! (Sparklet) pipeline, vs the paper's previous two-cluster workflow.
//!
//!   cargo run --release --example nowcasting

use std::sync::Arc;

use anyhow::Result;

use bigdl::bigdl::{inference, Adam, DistributedOptimizer, Module, TrainConfig};
use bigdl::data::radar::{radar_rdd, RadarConfig};
use bigdl::runtime::{default_artifacts_dir, RuntimeHandle};
use bigdl::sparklet::SparkletContext;

fn mse(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y) * (x - y)) as f64)
        .sum::<f64>()
        / a.len() as f64
}

fn main() -> Result<()> {
    bigdl::util::logging::init();
    let nodes = 4;
    let ctx = SparkletContext::local(nodes);
    let rt = RuntimeHandle::load(&default_artifacts_dir())?;
    let module = Module::load(&rt, "convlstm")?;
    let cfg = RadarConfig::default();

    // "over a terabyte of raw radar scan data" → a generated RDD of storm
    // sequences, converted to model tensors by the data pipeline.
    let train = radar_rdd(&ctx, cfg, nodes, 200, 31337);
    let mut optimizer = DistributedOptimizer::new(
        &ctx,
        module.clone(),
        train,
        Arc::new(Adam::new(0.005)),
        TrainConfig { iterations: 60, log_every: 10, ..Default::default() },
    )?;
    let report = optimizer.optimize()?;
    println!("training: {report}");

    // Nowcast the "next hour" on held-out storms; compare against the
    // persistence baseline (repeat the last seen frame — the standard
    // nowcasting strawman).
    let eval = radar_rdd(&ctx, cfg, nodes, 50, 777);
    let weights = Arc::new(optimizer.weights()?);
    let preds = inference::predict(&module, weights, &eval)?;
    let samples = eval.collect()?;
    let hw = cfg.size * cfg.size;
    let (mut model_mse, mut persist_mse) = (0.0, 0.0);
    for (sample, pred) in samples.iter().zip(&preds) {
        let target = sample.label.as_f32()?;
        let input = sample.features[0].as_f32()?;
        let last_frame = &input[(cfg.t_in - 1) * hw..cfg.t_in * hw];
        let persist: Vec<f32> = (0..cfg.t_out).flat_map(|_| last_frame.iter().copied()).collect();
        model_mse += mse(pred, target);
        persist_mse += mse(&persist, target);
    }
    model_mse /= samples.len() as f64;
    persist_mse /= samples.len() as f64;
    println!("nowcast MSE: model={model_mse:.5}  persistence={persist_mse:.5}");
    anyhow::ensure!(
        model_mse < persist_mse,
        "trained ConvLSTM should beat persistence ({model_mse} vs {persist_mse})"
    );
    anyhow::ensure!(
        report.final_loss < report.losses[0] * 0.7,
        "loss should drop: {:?} -> {}",
        report.losses[0],
        report.final_loss
    );
    println!("nowcasting OK");
    rt.shutdown();
    Ok(())
}

//! End-to-end validation driver (DESIGN.md §6 E9): train a transformer LM
//! on a synthetic Markov corpus for a few hundred steps across the
//! simulated cluster, logging the loss curve.
//!
//!   cargo run --release --example train_transformer -- \
//!       [--iterations 300] [--nodes 4] [--lr 3e-4] [--model transformer_e2e]
//!
//! Scale note: the paper-era "large" LM would be ~100M params; this
//! testbed is a single CPU core, so the default artifact is a 571k-param
//! GPT (same architecture, smaller dims — the dims are a config change in
//! python/compile/models/transformer.py). EXPERIMENTS.md §E9 records the
//! loss curve; the uniform baseline is ln(256) ≈ 5.545.

use std::io::Write;
use std::sync::Arc;

use anyhow::Result;

use bigdl::bigdl::{Adam, DistributedOptimizer, Module, TrainConfig};
use bigdl::data::corpus::{corpus_rdd, CorpusConfig};
use bigdl::runtime::{default_artifacts_dir, RuntimeHandle};
use bigdl::sparklet::SparkletContext;

fn main() -> Result<()> {
    bigdl::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, default: &str| -> String {
        args.windows(2)
            .rev()
            .find(|w| w[0] == format!("--{key}"))
            .map(|w| w[1].clone())
            .unwrap_or_else(|| default.to_string())
    };
    let iterations: usize = get("iterations", "300").parse()?;
    let nodes: usize = get("nodes", "4").parse()?;
    let lr: f32 = get("lr", "0.003").parse()?;
    let model_name = get("model", "transformer_e2e");

    let ctx = SparkletContext::local(nodes);
    let rt = RuntimeHandle::load(&default_artifacts_dir())?;
    let module = Module::load(&rt, &model_name)?;
    let entry = module.train_entry()?;
    let seq = entry.inputs[1].shape[1];
    println!(
        "model={model_name} params={} per-replica batch={} seq={} nodes={nodes} → global batch={} seqs ({} tokens)",
        module.param_count(),
        entry.batch_size,
        seq,
        entry.batch_size * nodes,
        entry.batch_size * nodes * seq,
    );

    let data = corpus_rdd(
        &ctx,
        CorpusConfig { seq_len: seq, ..Default::default() },
        nodes,
        256,
        99,
    );
    let mut optimizer = DistributedOptimizer::new(
        &ctx,
        module,
        data,
        Arc::new(Adam::new(lr)),
        TrainConfig { iterations, log_every: 10, ..Default::default() },
    )?;

    let t0 = std::time::Instant::now();
    let report = optimizer.optimize()?;
    let wall = t0.elapsed().as_secs_f64();

    // Loss curve to CSV for EXPERIMENTS.md.
    let mut csv = std::fs::File::create("train_transformer_loss.csv")?;
    writeln!(csv, "iteration,loss")?;
    for (i, l) in report.losses.iter().enumerate() {
        writeln!(csv, "{i},{l}")?;
    }

    let uniform = (256f32).ln();
    println!("\nloss curve (every 10th):");
    for (i, l) in report.losses.iter().enumerate().step_by(10) {
        let bar = "#".repeat(((l / uniform) * 50.0).clamp(0.0, 60.0) as usize);
        println!("  {i:>4}  {l:.4}  {bar}");
    }
    println!("\n{report}");
    println!(
        "tokens/sec: {:.0}  wall: {:.1}s  (uniform baseline {:.3})",
        report.records_per_sec * seq as f64,
        wall,
        uniform
    );
    // Pass bar scales with run length: short smoke runs must show clear
    // descent; the full few-hundred-step run must cut loss by >20%.
    let bar = if iterations >= 150 { report.losses[0] * 0.8 } else { report.losses[0] - 0.1 };
    anyhow::ensure!(
        report.final_loss < bar,
        "LM failed to learn: {} -> {} (bar {bar})",
        report.losses[0],
        report.final_loss
    );
    println!("train_transformer OK (loss {:.3} -> {:.3})", report.losses[0], report.final_loss);
    rt.shutdown();
    Ok(())
}

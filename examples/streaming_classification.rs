//! §5.3 (GigaSpaces) real-time streaming classification: speech-to-text
//! results flow through KafkaSim; a micro-batch streaming job classifies
//! each call with the (pre-trained) BigDL model and routes it to the
//! matching specialist queue.
//!
//!   cargo run --release --example streaming_classification

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use bigdl::bigdl::{
    inference, Adagrad, DistributedOptimizer, Module, PredictService, Reduced, Reduction, Sample,
    ServingConfig, TrainConfig,
};
use bigdl::data::textcat::{gen_document, textcat_rdd, TextcatConfig};
use bigdl::runtime::{default_artifacts_dir, RuntimeHandle};
use bigdl::sparklet::SparkletContext;
use bigdl::streaming::{KafkaSim, StreamingContext};
use bigdl::util::prng::Rng;

fn main() -> Result<()> {
    bigdl::util::logging::init();
    let nodes = 4;
    let ctx = SparkletContext::local(nodes);
    let rt = RuntimeHandle::load(&default_artifacts_dir())?;
    let module = Module::load(&rt, "textclf")?;
    let cfg = TextcatConfig::default();

    // Offline phase: train the intent classifier (as the paper's users
    // would have a pre-trained model).
    let train = textcat_rdd(&ctx, cfg, nodes, 400, 555);
    let mut optimizer = DistributedOptimizer::new(
        &ctx,
        module.clone(),
        train,
        Arc::new(Adagrad::new(0.1)),
        TrainConfig { iterations: 20, log_every: 0, ..Default::default() },
    )?;
    optimizer.optimize()?;

    // Hand the trained weights to a PredictService — shard-local
    // re-publication through the block store, no driver-side concat.
    let service: PredictService<Sample> =
        PredictService::new(&ctx, inference::module_scorer(&module)?, ServingConfig::default());
    optimizer.deploy_to(&service)?;

    // Online phase: a producer thread feeds "speech recognition results"
    // (token sequences) into the topic at ~2000 calls/sec.
    let topic: Arc<KafkaSim<Sample>> = KafkaSim::new(4096);
    let producer_topic = Arc::clone(&topic);
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(9001);
        for _ in 0..2000 {
            if !producer_topic.produce(gen_document(&cfg, &mut rng)) {
                break;
            }
            if rng.gen_bool(0.1) {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        producer_topic.close();
    });

    // Micro-batch classification through the service: scoring + argmax run
    // task-side, so only (class, correct) pairs reach the driver. (When no
    // label check is needed, `sc.classify_stream(&topic, 40, &service,
    // Reduction::Argmax, |i, preds| ...)` is the one-liner version.)
    let sc = StreamingContext::new(&ctx, Duration::from_millis(50), 512);
    let mut routed = vec![0usize; 5];
    let mut correct = 0usize;
    let mut total = 0usize;
    let stats = sc.run(&topic, 40, |_i, rdd| {
        let verdicts = service.score_partitions(&rdd, |rows, samples| {
            let mut out = Vec::with_capacity(rows.len());
            for (row, s) in rows.iter().zip(samples) {
                if let Reduced::Class { class, .. } = Reduction::Argmax.apply(row) {
                    out.push((class, class as i32 == s.label.as_i32()?[0]));
                }
            }
            Ok(out)
        })?;
        for (class, ok) in verdicts.into_iter().flatten() {
            routed[class] += 1; // → specialist queue `class`
            total += 1;
            if ok {
                correct += 1;
            }
        }
        Ok(())
    })?;
    producer.join().unwrap();

    let batches = stats.iter().filter(|s| s.records > 0).count();
    let p95 = {
        let mut t: Vec<f64> = stats.iter().filter(|s| s.records > 0).map(|s| s.process_s).collect();
        t.sort_by(f64::total_cmp);
        bigdl::util::stats::percentile(&t, 0.95)
    };
    let acc = correct as f64 / total.max(1) as f64;
    println!(
        "streamed {total} calls in {batches} micro-batches; routing accuracy {acc:.3}; \
         p95 batch latency {:.1}ms; queue depths {routed:?}",
        p95 * 1e3
    );
    anyhow::ensure!(total == 2000, "all produced calls must be classified (got {total})");
    anyhow::ensure!(acc > 0.5, "routing accuracy too low: {acc}");
    println!("streaming_classification OK");
    rt.shutdown();
    Ok(())
}

//! The §5.1 JD.com pipeline (paper Fig 9): read images → distributed
//! pre-processing → SSD object detection → crop the top-scoring box →
//! DeepBit feature extraction → store descriptors. All stages run as
//! coarse-grained RDD transforms + two distributed inference jobs in ONE
//! unified program (the point of the paper vs the "connector approach").
//!
//!   cargo run --release --example image_pipeline

use std::sync::Arc;

use anyhow::Result;

use bigdl::bigdl::{inference, Module, Sample};
use bigdl::data::imagenet_lite::{gen_image, ImagenetLiteConfig};
use bigdl::runtime::{default_artifacts_dir, RuntimeHandle};
use bigdl::sparklet::SparkletContext;
use bigdl::tensor::Tensor;

/// Nearest-neighbour crop+resize of a CHW image to (size × size).
fn crop_resize(img: &[f32], c: usize, s: usize, bx: &[f32], out_s: usize) -> Vec<f32> {
    let (cx, cy, w, h) = (bx[0], bx[1], bx[2].max(0.15), bx[3].max(0.15));
    let x0 = ((cx - w / 2.0).clamp(0.0, 1.0) * s as f32) as usize;
    let y0 = ((cy - h / 2.0).clamp(0.0, 1.0) * s as f32) as usize;
    let cw = ((w * s as f32) as usize).clamp(2, s - x0.min(s - 2));
    let ch = ((h * s as f32) as usize).clamp(2, s - y0.min(s - 2));
    let mut out = vec![0.0f32; c * out_s * out_s];
    for ci in 0..c {
        for oy in 0..out_s {
            for ox in 0..out_s {
                let sx = (x0 + ox * cw / out_s).min(s - 1);
                let sy = (y0 + oy * ch / out_s).min(s - 1);
                out[ci * out_s * out_s + oy * out_s + ox] = img[ci * s * s + sy * s + sx];
            }
        }
    }
    out
}

fn main() -> Result<()> {
    bigdl::util::logging::init();
    let nodes = 4;
    let n_images = 400;
    let ctx = SparkletContext::local(nodes);
    let rt = RuntimeHandle::load(&default_artifacts_dir())?;
    let ssd = Module::load(&rt, "ssd_lite")?;
    let deepbit = Module::load(&rt, "deepbit_lite")?;

    // Stage 1: "read hundreds of millions of pictures" — here a generated
    // RDD of 32x32 images (the SSD artifact's input size).
    let img_cfg = ImagenetLiteConfig { size: 32, ..Default::default() };
    let pictures = ctx
        .generate(nodes, n_images / nodes, 2024, move |_p, rng| gen_image(&img_cfg, rng))
        .cache();
    pictures.materialize_all()?;

    let t0 = std::time::Instant::now();

    // Stage 2: distributed object detection (scores + boxes per anchor).
    let ssd_w = Arc::new(ssd.initial_params()?);
    let det_rows = inference::predict(&ssd, ssd_w, &pictures)?; // scores row per sample
    // predict() returns the FIRST output (scores [A]); fetch boxes through
    // a second pass using the full predict API on partitions:
    let ssd2 = ssd.clone();
    let ssd_w2 = Arc::new(ssd.initial_params()?);
    let boxes_rows = {
        let entry = ssd.predict_entry()?.clone();
        pictures.run_partition_job(move |_tc, samples| {
            let mut rows: Vec<Vec<f32>> = Vec::with_capacity(samples.len());
            let mut start = 0;
            while start < samples.len() {
                let params = Tensor::from_f32(vec![ssd_w2.len()], ssd_w2.as_ref().clone());
                let (inputs, real) =
                    bigdl::bigdl::sample::assemble_predict_inputs(&entry, params, samples, start)?;
                let outs = ssd2.predict(inputs)?;
                let boxes = outs[1].as_f32()?; // [B, A, 4]
                let b = outs[1].shape[0];
                let row = outs[1].numel() / b;
                for r in 0..real {
                    rows.push(boxes[r * row..(r + 1) * row].to_vec());
                }
                start += real;
            }
            Ok(rows)
        })?
        .into_iter()
        .flatten()
        .collect::<Vec<_>>()
    };

    // Stage 3: keep the top-scoring box per picture and crop (RDD map).
    let imgs: Vec<Sample> = pictures.collect()?;
    let crops: Vec<Sample> = imgs
        .iter()
        .zip(det_rows.iter().zip(&boxes_rows))
        .map(|(sample, (scores, boxes))| {
            let best = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            let img = sample.features[0].as_f32().unwrap();
            let crop = crop_resize(img, 3, 32, &boxes[best * 4..best * 4 + 4], 16);
            Sample::new(
                vec![Tensor::from_f32(vec![3, 16, 16], crop)],
                Tensor::from_f32(vec![], vec![scores[best]]),
            )
        })
        .collect();
    let target_rdd = ctx.parallelize(crops, nodes);

    // Stage 4: distributed DeepBit feature extraction + binarization.
    let db_w = Arc::new(deepbit.initial_params()?);
    let descriptors = inference::predict_map(&deepbit, db_w, &target_rdd, |bits| {
        let mut v: u32 = 0;
        for (i, b) in bits.iter().enumerate().take(32) {
            if *b >= 0.5 {
                v |= 1 << i;
            }
        }
        v
    })?;

    let wall = t0.elapsed().as_secs_f64();
    let distinct: std::collections::HashSet<u32> = descriptors.iter().copied().collect();
    println!(
        "pipeline: {} images → {} binary descriptors ({} distinct) in {wall:.2}s  ({:.1} img/s)",
        n_images,
        descriptors.len(),
        distinct.len(),
        n_images as f64 / wall
    );
    anyhow::ensure!(descriptors.len() == n_images);
    anyhow::ensure!(distinct.len() > 4, "descriptors should vary across images");
    println!("image_pipeline OK");
    rt.shutdown();
    Ok(())
}

//! Quickstart — the paper's Figure 1 pipeline, end to end:
//! distributed data processing (RDD transforms) → distributed training
//! (Algorithm 1/2) → distributed inference, in one unified program.
//!
//!   cargo run --release --example quickstart
//!
//! Requires `make artifacts`.

use std::sync::Arc;

use anyhow::Result;

use bigdl::bigdl::{inference, metrics, Adagrad, DistributedOptimizer, Module, TrainConfig};
use bigdl::data::textcat::{textcat_rdd, TextcatConfig};
use bigdl::runtime::{default_artifacts_dir, RuntimeHandle};
use bigdl::sparklet::SparkletContext;

fn main() -> Result<()> {
    bigdl::util::logging::init();
    let nodes = 4;

    // -- distributed data processing (Fig 1 lines 1-6) -----------------------
    let ctx = SparkletContext::local(nodes);
    let rt = RuntimeHandle::load(&default_artifacts_dir())?;
    let cfg = TextcatConfig::default();
    let raw = textcat_rdd(&ctx, cfg, nodes, 400, 1234);
    // Coarse-grained transforms, as a real pipeline would do: drop
    // truncated docs, then a keyed aggregation for a class-balance check
    // (Spark-style pair-RDD ops over the same data).
    let train = raw.filter(|s| s.features[0].numel() == 16).cache();
    let class_counts = train
        .key_by(|s| s.label.as_i32().map(|l| l[0]).unwrap_or(-1))
        .count_by_key()?;
    println!("records: {} per-class: {:?}", train.count()?, {
        let mut c: Vec<_> = class_counts.into_iter().collect();
        c.sort();
        c
    });

    // -- distributed training (Fig 1 lines 8-14) -----------------------------
    let module = Module::load(&rt, "textclf")?;
    let mut optimizer = DistributedOptimizer::new(
        &ctx,
        module.clone(),
        train,
        Arc::new(Adagrad::new(0.1)),
        TrainConfig { iterations: 25, log_every: 5, ..Default::default() },
    )?;
    let report = optimizer.optimize()?;
    println!("training: {report}");

    // -- distributed inference (Fig 1 lines 16-18) ---------------------------
    let test = textcat_rdd(&ctx, cfg, nodes, 150, 777);
    let weights = Arc::new(optimizer.weights()?);
    let rows = inference::predict(&module, weights, &test)?;
    let labels: Vec<i32> = test
        .collect()?
        .iter()
        .map(|s| s.label.as_i32().unwrap()[0])
        .collect();
    let acc = metrics::top1_accuracy(&rows, &labels);
    println!("held-out accuracy: {acc:.3} (chance = {:.3})", 1.0 / 5.0);
    anyhow::ensure!(acc > 0.5, "quickstart model failed to learn (acc {acc})");
    println!("quickstart OK");
    rt.shutdown();
    Ok(())
}

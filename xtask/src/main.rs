//! Repo conformance lint: `cargo xtask lint`.
//!
//! A deliberately dependency-free *lexical* scanner (no `syn`, no proc
//! macros): it strips comments and string literals, then matches a small
//! set of token patterns. That keeps it fast, runnable on any toolchain,
//! and immune to the "lint crate needs a newer compiler than the tree"
//! failure mode — at the cost of being approximate. The rules are chosen
//! so the approximation is sound for this codebase (see the fixture
//! tests at the bottom, which pin both the hits and the non-hits).
//!
//! Rules:
//!
//! 1. `raw-lock` — the identifiers `Mutex` / `RwLock` may not appear
//!    outside `rust/src/util/sync.rs`. All lock acquisition must go
//!    through `OrderedMutex` / `OrderedRwLock` so the debug-build
//!    lock-order checker sees every edge. (`OrderedMutex` itself does
//!    not match: the identifier boundary check requires the character
//!    before `Mutex` to not be part of an identifier.)
//! 2. `lock-unwrap` — `.lock().unwrap()` is banned everywhere. The
//!    ordered primitives recover from poison instead of propagating it;
//!    a raw `.unwrap()` on a lock result turns one task panic into a
//!    cascade across every thread that touches the lock afterwards.
//! 3. `task-determinism` — `Instant::now`, `SystemTime::now` and
//!    `thread_rng` are banned inside task closures (closures whose
//!    first parameter is literally `tc`, the `TaskContext` binding used
//!    across the codebase). Tasks must draw time/randomness from the
//!    `TaskContext` so replays and retries are deterministic.
//! 4. `allow-deprecated` — `#[allow(deprecated)]` is banned; deprecated
//!    shims must be migrated, not silenced.
//! 5. `bare-unwrap` — `.unwrap()` is banned in the scheduler and
//!    cluster (the failure-handling core); use `.expect("invariant")`
//!    so a violated invariant names itself in the panic message.
//!
//! Waivers: any *raw* source line containing the marker `lint:allow`
//! (conventionally `// lint:allow(<rule>): <reason>`) is exempt from
//! every rule on that line. Waivers are greppable, so the exception
//! budget stays visible.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") | None => {}
        Some(other) => {
            eprintln!("unknown xtask command `{other}` (expected: lint)");
            return ExitCode::FAILURE;
        }
    }
    // xtask lives at <repo>/xtask; the tree under lint is <repo>/rust.
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask crate sits one level below the workspace root")
        .to_path_buf();
    let violations = lint_tree(&repo_root);
    if violations.is_empty() {
        println!("xtask lint: clean");
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!("xtask lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

fn lint_tree(repo_root: &Path) -> Vec<Violation> {
    let mut files = Vec::new();
    for sub in ["rust/src", "rust/tests", "rust/benches"] {
        collect_rs_files(&repo_root.join(sub), &mut files);
    }
    files.sort();
    let mut violations = Vec::new();
    for path in &files {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                violations.push(Violation {
                    file: path.display().to_string(),
                    line: 0,
                    rule: "io",
                    msg: format!("unreadable: {e}"),
                });
                continue;
            }
        };
        let rel = path
            .strip_prefix(repo_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        violations.extend(lint_file(&rel, &text));
    }
    violations
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return, // absent subtree (e.g. no benches/) is fine
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Lint one file. `rel` is the repo-relative path with `/` separators —
/// rules 1 and 5 are scoped by path.
fn lint_file(rel: &str, raw: &str) -> Vec<Violation> {
    let stripped = strip_comments_and_strings(raw);
    let raw_lines: Vec<&str> = raw.lines().collect();
    let waived = |line: usize| {
        raw_lines
            .get(line - 1)
            .is_some_and(|l| l.contains("lint:allow"))
    };
    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, msg: String| {
        if !waived(line) {
            out.push(Violation { file: rel.to_string(), line, rule, msg });
        }
    };

    let in_sync_rs = rel == "rust/src/util/sync.rs";
    let unwrap_audited =
        rel == "rust/src/sparklet/scheduler.rs" || rel == "rust/src/sparklet/cluster.rs";

    for (idx, line) in stripped.lines().enumerate() {
        let lineno = idx + 1;
        if !in_sync_rs {
            for ident in ["Mutex", "RwLock"] {
                if contains_identifier(line, ident) {
                    push(
                        lineno,
                        "raw-lock",
                        format!(
                            "raw `{ident}` outside util/sync.rs — use Ordered{ident} \
                             so the lock-order checker sees this site"
                        ),
                    );
                }
            }
        }
        if line.contains(".lock().unwrap()") {
            push(
                lineno,
                "lock-unwrap",
                "`.lock().unwrap()` turns one poisoned lock into a panic cascade; \
                 OrderedMutex::lock recovers from poison"
                    .to_string(),
            );
        }
        if line.contains("#[allow(deprecated)]") {
            push(
                lineno,
                "allow-deprecated",
                "`#[allow(deprecated)]` silences a migration instead of doing it".to_string(),
            );
        }
        if unwrap_audited && line.contains(".unwrap()") {
            push(
                lineno,
                "bare-unwrap",
                "bare `.unwrap()` in the scheduler/cluster core — use \
                 `.expect(\"<invariant>\")` so the panic names what broke"
                    .to_string(),
            );
        }
    }

    for (lineno, token) in determinism_in_task_closures(&stripped) {
        if !waived(lineno) {
            out.push(Violation {
                file: rel.to_string(),
                line: lineno,
                rule: "task-determinism",
                msg: format!(
                    "`{token}` inside a task closure — tasks must take time/randomness \
                     from the TaskContext so retries and replays are deterministic"
                ),
            });
        }
    }
    out
}

/// True when `ident` appears in `line` as a standalone identifier (not a
/// suffix of a longer one like `OrderedMutex`, and not a prefix either).
fn contains_identifier(line: &str, ident: &str) -> bool {
    let bytes = line.as_bytes();
    let is_ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut from = 0;
    while let Some(pos) = line[from..].find(ident) {
        let start = from + pos;
        let end = start + ident.len();
        let before_ok = start == 0 || !is_ident(bytes[start - 1]);
        let after_ok = end == bytes.len() || !is_ident(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

/// Find forbidden wall-clock / RNG tokens lexically inside closures whose
/// first parameter is `tc` (the TaskContext binding convention). Returns
/// (line, token) pairs. Works on stripped source: tracks brace depth, and
/// treats `|tc|` / `|tc,` / `|tc:` as the start of a task closure whose
/// body is the `{ ... }` block opened next at the same nesting level.
fn determinism_in_task_closures(stripped: &str) -> Vec<(usize, &'static str)> {
    const TOKENS: [&str; 3] = ["Instant::now", "SystemTime::now", "thread_rng"];
    let bytes = stripped.as_bytes();
    let mut hits = Vec::new();
    let mut line = 1usize;
    let mut depth = 0i32;
    // Stack of brace depths at which a task-closure body opened; while
    // non-empty we are (lexically) inside at least one task closure.
    let mut task_body_depths: Vec<i32> = Vec::new();
    // Set when `|tc...|` was seen and we are waiting for its body `{`.
    let mut pending_body = false;
    let mut i = 0usize;
    while i < bytes.len() {
        let b = bytes[i];
        match b {
            b'\n' => line += 1,
            b'{' => {
                depth += 1;
                if pending_body {
                    task_body_depths.push(depth);
                    pending_body = false;
                }
            }
            b'}' => {
                if task_body_depths.last() == Some(&depth) {
                    task_body_depths.pop();
                }
                depth -= 1;
            }
            b'|' => {
                // `|tc` followed by `|`, `,` or `:` — a closure binding
                // the TaskContext. (`||` and `a | b` don't match.)
                if bytes[i..].starts_with(b"|tc")
                    && matches!(bytes.get(i + 3), Some(b'|' | b',' | b':'))
                {
                    pending_body = true;
                }
            }
            // A `;` before the body `{` means the closure was braceless
            // (e.g. `.map(|tc| tc.node);`) — nothing to track.
            b';' => pending_body = false,
            _ => {
                if !task_body_depths.is_empty() {
                    for tok in TOKENS {
                        if bytes[i..].starts_with(tok.as_bytes())
                            && (i == 0 || !is_ident_byte(bytes[i - 1]))
                        {
                            hits.push((line, tok));
                            i += tok.len() - 1; // skip; outer loop adds 1
                            break;
                        }
                    }
                }
            }
        }
        i += 1;
    }
    hits
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Replace the contents of comments, string/char literals and raw strings
/// with spaces, preserving newlines so line numbers survive. This is what
/// makes the lexical rules sound: `// Mutex` and `"Mutex"` never match.
fn strip_comments_and_strings(src: &str) -> String {
    let bytes = src.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0usize;
    let blank = |out: &mut Vec<u8>, bytes: &[u8], from: usize, to: usize| {
        for &b in &bytes[from..to] {
            out.push(if b == b'\n' { b'\n' } else { b' ' });
        }
    };
    while i < bytes.len() {
        let b = bytes[i];
        // Line comment.
        if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
            let end = src[i..].find('\n').map_or(bytes.len(), |p| i + p);
            blank(&mut out, bytes, i, end);
            i = end;
            continue;
        }
        // Block comment (nestable in Rust).
        if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
            let mut depth = 1;
            let mut j = i + 2;
            while j < bytes.len() && depth > 0 {
                if bytes[j] == b'/' && bytes.get(j + 1) == Some(&b'*') {
                    depth += 1;
                    j += 2;
                } else if bytes[j] == b'*' && bytes.get(j + 1) == Some(&b'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, bytes, i, j);
            i = j;
            continue;
        }
        // Raw string: r"..." / r#"..."# / br#"..."# etc.
        if b == b'r' || (b == b'b' && bytes.get(i + 1) == Some(&b'r')) {
            let r_at = if b == b'r' { i } else { i + 1 };
            // Must be a fresh token, not the tail of an identifier.
            let fresh = i == 0 || !is_ident_byte(bytes[i - 1]);
            let mut j = r_at + 1;
            while fresh && bytes.get(j) == Some(&b'#') {
                j += 1;
            }
            if fresh && bytes.get(j) == Some(&b'"') {
                let hashes = j - (r_at + 1);
                let close = format!("\"{}", "#".repeat(hashes));
                let body_start = j + 1;
                let end = src[body_start..]
                    .find(&close)
                    .map_or(bytes.len(), |p| body_start + p + close.len());
                // Keep the delimiters visible, blank the contents.
                for &d in &bytes[i..body_start] {
                    out.push(d);
                }
                blank(&mut out, bytes, body_start, end);
                i = end;
                continue;
            }
        }
        // Ordinary string (or byte string — the b was pushed already if
        // it wasn't part of a raw string).
        if b == b'"' {
            let mut j = i + 1;
            while j < bytes.len() {
                match bytes[j] {
                    b'\\' => j += 2,
                    b'"' => {
                        j += 1;
                        break;
                    }
                    _ => j += 1,
                }
            }
            out.push(b'"');
            blank(&mut out, bytes, i + 1, j.saturating_sub(1).max(i + 1));
            if j > i + 1 {
                out.push(b'"');
            }
            i = j;
            continue;
        }
        // Char literal vs lifetime: 'x' or '\n' is a literal; 'static is
        // a lifetime and must be left alone (it has no closing quote).
        if b == b'\'' {
            let is_escape = bytes.get(i + 1) == Some(&b'\\');
            let closes_after_one =
                bytes.get(i + 2) == Some(&b'\'') && bytes.get(i + 1) != Some(&b'\\');
            if is_escape {
                // '\x' .. find closing quote
                let mut j = i + 2;
                while j < bytes.len() && bytes[j] != b'\'' {
                    j += 1;
                }
                let end = (j + 1).min(bytes.len());
                out.push(b'\'');
                blank(&mut out, bytes, i + 1, end.saturating_sub(1));
                out.push(b'\'');
                i = end;
                continue;
            } else if closes_after_one {
                out.extend_from_slice(b"' '");
                i += 3;
                continue;
            }
            // Lifetime — fall through, push the quote as-is.
        }
        out.push(b);
        i += 1;
    }
    String::from_utf8(out).expect("stripping only substitutes ASCII spaces")
}

// ---------------------------------------------------------------------------
// Fixture tests: prove the lint FAILS on seeded violations and PASSES on the
// idioms the tree actually uses. CI runs these via `cargo test -p xtask`.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, src: &str) -> Vec<&'static str> {
        lint_file(rel, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn raw_mutex_outside_sync_rs_is_flagged() {
        let src = "use std::sync::Mutex;\nstatic S: Mutex<u32> = Mutex::new(0);\n";
        let got = rules("rust/src/sparklet/cluster.rs", src);
        assert_eq!(got, ["raw-lock", "raw-lock"]);
    }

    #[test]
    fn raw_rwlock_is_flagged_but_ordered_variants_pass() {
        assert_eq!(rules("rust/src/a.rs", "let x: RwLock<u8>;\n"), ["raw-lock"]);
        let clean = "use crate::util::sync::{OrderedMutex, OrderedRwLock};\n\
                     let m = OrderedMutex::new(rank::LEAF, 0);\n";
        assert!(rules("rust/src/a.rs", clean).is_empty());
    }

    #[test]
    fn sync_rs_itself_may_use_raw_locks() {
        let src = "use std::sync::{Mutex, RwLock};\n";
        assert!(rules("rust/src/util/sync.rs", src).is_empty());
    }

    #[test]
    fn mutex_in_comments_and_strings_is_ignored() {
        let src = "// a Mutex in prose\nlet s = \"Mutex\"; /* RwLock */\n";
        assert!(rules("rust/src/a.rs", src).is_empty());
    }

    #[test]
    fn lock_unwrap_is_flagged() {
        let src = "let g = self.inner.lock().unwrap();\n";
        assert_eq!(rules("rust/src/a.rs", src), ["lock-unwrap"]);
    }

    #[test]
    fn allow_deprecated_is_flagged_unless_commented() {
        assert_eq!(rules("rust/src/a.rs", "#[allow(deprecated)]\nfn f() {}\n"),
                   ["allow-deprecated"]);
        assert!(rules("rust/src/a.rs", "// #[allow(deprecated)]\n").is_empty());
    }

    #[test]
    fn bare_unwrap_only_audited_in_core_files() {
        let src = "let v = map.get(&k).unwrap();\n";
        assert_eq!(rules("rust/src/sparklet/scheduler.rs", src), ["bare-unwrap"]);
        assert_eq!(rules("rust/src/sparklet/cluster.rs", src), ["bare-unwrap"]);
        assert!(rules("rust/src/bigdl/optimizer.rs", src).is_empty());
        let expect = "let v = map.get(&k).expect(\"slot registered at join\");\n";
        assert!(rules("rust/src/sparklet/scheduler.rs", expect).is_empty());
    }

    #[test]
    fn wall_clock_inside_task_closure_is_flagged() {
        let src = "\
fn driver() {
    let t = Instant::now(); // driver side: fine
    let task = move |tc: &TaskContext| {
        let t0 = Instant::now();
        let mut rng = thread_rng();
        Ok(())
    };
}
";
        let got = lint_file("rust/src/a.rs", src);
        let lines: Vec<_> = got.iter().map(|v| (v.rule, v.line)).collect();
        assert_eq!(lines, [("task-determinism", 4), ("task-determinism", 5)]);
    }

    #[test]
    fn task_closure_detection_handles_bare_and_two_param_forms() {
        let src = "\
let a = Arc::new(move |tc| {
    let now = SystemTime::now();
});
let b = move |tc: &TaskContext, samples: &[Sample]| {
    let t = Instant::now();
};
";
        let got = rules("rust/src/a.rs", src);
        assert_eq!(got, ["task-determinism", "task-determinism"]);
    }

    #[test]
    fn wall_clock_after_closure_body_closes_is_clean() {
        let src = "\
fn f() {
    run(move |tc| {
        work(tc);
    });
    let t = Instant::now();
}
";
        assert!(rules("rust/src/a.rs", src).is_empty());
    }

    #[test]
    fn waiver_marker_exempts_the_line() {
        let src = "\
let task = move |tc: &TaskContext| {
    let t0 = Instant::now(); // lint:allow(task-determinism): metering only
    let t1 = Instant::now();
};
";
        let got = lint_file("rust/src/a.rs", src);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].line, 3);
    }

    #[test]
    fn or_patterns_and_closures_without_tc_do_not_trigger() {
        let src = "\
let f = |x| x + 1;
let y = a | b;
match v { 1 | 2 => {} _ => {} }
let t = Instant::now();
";
        assert!(rules("rust/src/a.rs", src).is_empty());
    }

    #[test]
    fn braceless_tc_closure_does_not_poison_later_blocks() {
        let src = "\
fn f(tasks: &[TaskContext]) {
    let ids: Vec<_> = tasks.iter().map(|tc| tc.node).collect();
    if !ids.is_empty() {
        let t = Instant::now();
    }
}
";
        assert!(rules("rust/src/a.rs", src).is_empty());
    }

    #[test]
    fn raw_strings_and_char_literals_are_stripped_safely() {
        let src = "let s = r#\"Mutex .lock().unwrap()\"#;\nlet c = '\"'; let l: &'static str = \"RwLock\";\n";
        assert!(rules("rust/src/a.rs", src).is_empty());
    }

    #[test]
    fn stripping_preserves_line_numbers() {
        let src = "/* multi\nline\ncomment */\nlet m: Mutex<u8>;\n";
        let got = lint_file("rust/src/a.rs", src);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].line, 4);
    }

    /// The real tree must be clean — this is the same check CI runs via
    /// `cargo xtask lint`, embedded as a test so `cargo test` alone
    /// catches regressions too.
    #[test]
    fn repo_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("workspace root")
            .to_path_buf();
        let violations = lint_tree(&root);
        assert!(
            violations.is_empty(),
            "lint violations in tree:\n{}",
            violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}

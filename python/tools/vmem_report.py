"""L1/L2 §Perf report: VMEM footprint + MXU utilization estimates for the
Pallas matmul tile configs used by each model's GEMMs, plus HLO op-mix
stats for every exported artifact (fusion effectiveness proxy).

interpret=True wallclock is CPU-numpy time, NOT a TPU proxy — this report
is the structural evidence the §Perf L1/L2 targets are judged on.

Usage: (cd python && python -m tools.vmem_report [--artifacts ../artifacts])
"""

import argparse
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels import matmul as mm  # noqa: E402

# Representative GEMM shapes per model (M = batch·seq rows, K, N).
MODEL_GEMMS = {
    "ncf (fc0)": (128, 64, 64),
    "ncf (out)": (128, 32, 1),
    "transformer_e2e (qkv)": (8 * 64, 128, 384),
    "transformer_e2e (ff1)": (8 * 64, 128, 256),
    "transformer_e2e (lm head)": (8 * 64, 128, 256),
    "inception_lite (3x3 conv as GEMM)": (32 * 16 * 16, 3 * 9, 24),
    "textclf (lstm gates)": (32, 32, 256),
    "convlstm (enc gates)": (4 * 16 * 16, 9 * 9, 32),
}

TILE_CONFIGS = [(128, 128, 128), (128, 128, 64), (64, 64, 64), (32, 32, 128)]

VMEM_BUDGET = 16 * 1024 * 1024  # v4/v5 ≈ 16 MiB/core


def tile_report():
    print("== L1: Pallas matmul tile configs (VMEM + MXU structure) ==")
    print(f"{'tile (bm,bn,bk)':>18} {'VMEM (dbl-buf)':>16} {'fits 16MiB':>11}")
    for bm, bn, bk in TILE_CONFIGS:
        v = mm.vmem_bytes(bm, bn, bk)
        print(f"{str((bm, bn, bk)):>18} {v / 1024:>13.0f}KiB {str(v < VMEM_BUDGET):>11}")
    print("\n== per-model GEMM MXU utilization: naive 128³ vs adaptive tiles ==")
    print("(the kernel shrinks blocks to lane-aligned covers of small dims —")
    print(" `matmul.py` bm/bn/bk = min(128, ceil8(dim)); this is §Perf L1-1)")
    print(f"{'gemm':>36} {'M,K,N':>20} {'naive':>6} {'adaptive':>9} {'tile':>16}")
    for name, (m, k, n) in MODEL_GEMMS.items():
        naive = mm.mxu_utilization(m, n, k)
        ce = mm._ceil_mult
        bm, bn, bk = min(128, ce(m)), min(128, ce(n)), min(128, ce(k))
        adaptive = mm.mxu_utilization(m, n, k, bm, bn, bk)
        print(
            f"{name:>36} {str((m, k, n)):>20} {naive:>6.2f} {adaptive:>9.2f} "
            f"{str((bm, bn, bk)):>16}"
        )


def hlo_report(artifacts: str):
    print("\n== L2: HLO op mix per artifact (fusion effectiveness) ==")
    print(f"{'artifact':>34} {'ops':>6} {'fusion':>7} {'dot':>5} {'conv':>5} {'while':>6} {'custom':>7}")
    for f in sorted(os.listdir(artifacts)):
        if not f.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(artifacts, f)).read()
        ops = len(re.findall(r"^\s+\S+ = ", text, re.M))
        counts = {
            k: len(re.findall(rf"^\s+\S+ = \S* ?{k}", text, re.M))
            for k in ["fusion", "dot", "convolution", "while", "custom-call"]
        }
        print(
            f"{f:>34} {ops:>6} {counts['fusion']:>7} {counts['dot']:>5} "
            f"{counts['convolution']:>5} {counts['while']:>6} {counts['custom-call']:>7}"
        )
    print("\n(custom-call must be 0: interpret-mode Pallas lowers to plain HLO,")
    print(" so every artifact runs on the CPU PJRT client.)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    tile_report()
    if os.path.isdir(args.artifacts):
        hlo_report(args.artifacts)
    else:
        print(f"(skipping HLO report: {args.artifacts} missing)")


if __name__ == "__main__":
    main()

"""Inception-lite — the Fig 6/7/8 scaling workload, scaled to the testbed.

The paper trains Inception-v1 on ImageNet; the scaling figures depend on
the ratio (per-minibatch compute time) : (parameter bytes), which NetSim
parameterizes to the paper's values. For *real-mode* runs we use this
small inception-style CNN on 16x16 synthetic images: a stem conv + two
inception blocks (1x1 / 3x3 / 5x5-as-double-3x3 / pool-proj branches) +
global average pooling. All convs are im2col + the Pallas GEMM.
"""

import jax
import jax.numpy as jnp

from . import common


def config(scale="small"):
    if scale == "small":
        return dict(classes=10, channels=3, size=16, stem=16,
                    b1x1=16, b3x3=24, b5x5=8, bpool=8)
    raise ValueError(scale)


def _block_params(rng, prefix, c_in, cfg, params):
    k = jax.random.split(rng, 5)
    common.conv_params(k[0], c_in, cfg["b1x1"], 1, f"{prefix}_1x1", params)
    common.conv_params(k[1], c_in, cfg["b3x3"], 3, f"{prefix}_3x3", params)
    # 5x5 as two stacked 3x3 (as Inception-v3 rethought it — cheaper on MXU).
    common.conv_params(k[2], c_in, cfg["b5x5"], 3, f"{prefix}_5a", params)
    common.conv_params(k[3], cfg["b5x5"], cfg["b5x5"], 3, f"{prefix}_5b", params)
    common.conv_params(k[4], c_in, cfg["bpool"], 1, f"{prefix}_pool", params)
    return cfg["b1x1"] + cfg["b3x3"] + cfg["b5x5"] + cfg["bpool"]


def init_params(rng, cfg):
    params = {}
    k = jax.random.split(rng, 4)
    common.conv_params(k[0], cfg["channels"], cfg["stem"], 3, "stem", params)
    c1 = _block_params(k[1], "inc1", cfg["stem"], cfg, params)
    c2 = _block_params(k[2], "inc2", c1, cfg, params)
    params["head_w"] = common.glorot(k[3], (c2, cfg["classes"]))
    params["head_b"] = common.zeros((cfg["classes"],))
    return params


def _block(params, prefix, x):
    b1 = common.conv2d(x, params[f"{prefix}_1x1_w"], params[f"{prefix}_1x1_b"],
                       activation="relu")
    b3 = common.conv2d(x, params[f"{prefix}_3x3_w"], params[f"{prefix}_3x3_b"],
                       activation="relu")
    b5 = common.conv2d(x, params[f"{prefix}_5a_w"], params[f"{prefix}_5a_b"],
                       activation="relu")
    b5 = common.conv2d(b5, params[f"{prefix}_5b_w"], params[f"{prefix}_5b_b"],
                       activation="relu")
    bp = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 1, 1), "SAME"
    )
    bp = common.conv2d(bp, params[f"{prefix}_pool_w"], params[f"{prefix}_pool_b"],
                       activation="relu")
    return jnp.concatenate([b1, b3, b5, bp], axis=1)


def _logits(params, images):
    x = common.conv2d(images, params["stem_w"], params["stem_b"], activation="relu")
    x = _block(params, "inc1", x)
    # Spatial downsample between blocks (stride-2 max pool).
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    x = _block(params, "inc2", x)
    x = jnp.mean(x, axis=(2, 3))  # global average pool
    return common.dense(x, params["head_w"], params["head_b"], "none")


def loss_fn(params, batch, cfg):
    images, labels = batch
    return common.softmax_xent(_logits(params, images), labels)


def predict_fn(params, inputs, cfg):
    (images,) = inputs
    return (jax.nn.softmax(_logits(params, images), axis=-1),)


def batch_spec(cfg, b):
    c, s = cfg["channels"], cfg["size"]
    return [
        jax.ShapeDtypeStruct((b, c, s, s), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    ]


def predict_spec(cfg, b):
    c, s = cfg["channels"], cfg["size"]
    return [jax.ShapeDtypeStruct((b, c, s, s), jnp.float32)]

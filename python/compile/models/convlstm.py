"""ConvLSTM Seq2Seq — the §5.2 precipitation-nowcasting model (Cray):
a stacked-free (single-layer, testbed-scaled) ConvLSTM encoder over t_in
radar frames, a ConvLSTM decoder rolling out t_out future frames, and a
1x1 conv readout. Gate convolutions are im2col + the Pallas GEMM.
"""

import jax
import jax.numpy as jnp

from . import common


def config(scale="small"):
    if scale == "small":
        return dict(size=16, t_in=4, t_out=4, hidden=8, k=3)
    raise ValueError(scale)


def init_params(rng, cfg):
    h, k = cfg["hidden"], cfg["k"]
    params = {}
    keys = jax.random.split(rng, 3)
    # Encoder gates: input (1ch) + hidden → 4h channels.
    common.conv_params(keys[0], 1 + h, 4 * h, k, "enc", params)
    # Decoder gates: hidden-only input (autoregressive on state).
    common.conv_params(keys[1], h, 4 * h, k, "dec", params)
    common.conv_params(keys[2], h, 1, 1, "out", params)
    return params


def _cell(params, prefix, x, h, c):
    """One ConvLSTM step. x may be None (decoder)."""
    inp = h if x is None else jnp.concatenate([x, h], axis=1)
    gates = common.conv2d(inp, params[f"{prefix}_w"], params[f"{prefix}_b"])
    i, f, g, o = jnp.split(gates, 4, axis=1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c = f * c + i * g
    h = o * jnp.tanh(c)
    return h, c


def _rollout(params, frames, cfg):
    bsz = frames.shape[0]
    s, hid = cfg["size"], cfg["hidden"]
    h = jnp.zeros((bsz, hid, s, s))
    c = jnp.zeros((bsz, hid, s, s))
    for t in range(cfg["t_in"]):
        x = frames[:, t][:, None]  # [B,1,H,W]
        h, c = _cell(params, "enc", x, h, c)
    outs = []
    for _ in range(cfg["t_out"]):
        h, c = _cell(params, "dec", None, h, c)
        outs.append(common.conv2d(h, params["out_w"], params["out_b"])[:, 0])
    return jnp.stack(outs, axis=1)  # [B,t_out,H,W]


def loss_fn(params, batch, cfg):
    frames, target = batch
    pred = _rollout(params, frames, cfg)
    return jnp.mean(jnp.square(pred - target))


def predict_fn(params, inputs, cfg):
    (frames,) = inputs
    return (_rollout(params, frames, cfg),)


def batch_spec(cfg, b):
    s = cfg["size"]
    return [
        jax.ShapeDtypeStruct((b, cfg["t_in"], s, s), jnp.float32),
        jax.ShapeDtypeStruct((b, cfg["t_out"], s, s), jnp.float32),
    ]


def predict_spec(cfg, b):
    s = cfg["size"]
    return [jax.ShapeDtypeStruct((b, cfg["t_in"], s, s), jnp.float32)]

"""Text classifier — the paper's Figure 1 pipeline (embedding → recurrent
encoder → linear → log-softmax). The recurrent cell is an LSTM whose gate
matmuls run through the Pallas GEMM; unrolled over the (short) sequence.
"""

import jax
import jax.numpy as jnp

from . import common


def config(scale="small"):
    if scale == "small":
        return dict(vocab=1000, seq=16, emb=32, hidden=64, classes=5)
    raise ValueError(scale)


def init_params(rng, cfg):
    e, h = cfg["emb"], cfg["hidden"]
    k = jax.random.split(rng, 4)
    return {
        "emb": common.normal(k[0], (cfg["vocab"], e), scale=0.05),
        "lstm_wx": common.glorot(k[1], (e, 4 * h)),
        "lstm_wh": common.glorot(k[2], (h, 4 * h)),
        "lstm_b": common.zeros((4 * h,)),
        "head_w": common.glorot(k[3], (h, cfg["classes"])),
        "head_b": common.zeros((cfg["classes"],)),
    }


def _encode(params, tokens, cfg):
    bsz, t = tokens.shape
    h = jnp.zeros((bsz, cfg["hidden"]))
    c = jnp.zeros((bsz, cfg["hidden"]))
    x = params["emb"][tokens]  # [B,T,E]
    zeros_b = jnp.zeros((4 * cfg["hidden"],))
    for step in range(t):
        gx = common.dense(x[:, step], params["lstm_wx"], params["lstm_b"], "none")
        gh = common.dense(h, params["lstm_wh"], zeros_b, "none")
        i, f, g, o = jnp.split(gx + gh, 4, axis=1)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
        c = f * c + i * jnp.tanh(g)
        h = o * jnp.tanh(c)
    return h


def _logits(params, tokens, cfg):
    h = _encode(params, tokens, cfg)
    return common.dense(h, params["head_w"], params["head_b"], "none")


def loss_fn(params, batch, cfg):
    tokens, labels = batch
    return common.softmax_xent(_logits(params, tokens, cfg), labels)


def predict_fn(params, inputs, cfg):
    (tokens,) = inputs
    return (jax.nn.softmax(_logits(params, tokens, cfg), axis=-1),)


def batch_spec(cfg, b):
    return [
        jax.ShapeDtypeStruct((b, cfg["seq"]), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    ]


def predict_spec(cfg, b):
    return [jax.ShapeDtypeStruct((b, cfg["seq"]), jnp.int32)]

"""Neural Collaborative Filtering (He et al., WWW'17) — the Fig-5 workload.

NeuMF architecture: a GMF tower (elementwise product of user/item
embeddings) concatenated with an MLP tower (dense stack over concatenated
embeddings, via the Pallas `dense` layer), projected to a single logit.
Trained with BCE on implicit feedback, exactly as the MLPerf reference the
paper benchmarks against.
"""

import jax
import jax.numpy as jnp

from . import common


def config(scale="small"):
    if scale == "small":
        return dict(n_users=2048, n_items=1024, gmf_dim=16,
                    mlp_emb=32, mlp_hidden=(64, 32, 16))
    if scale == "medium":  # closer to ml-20m shape, scaled 10x down
        return dict(n_users=13800, n_items=2700, gmf_dim=32,
                    mlp_emb=64, mlp_hidden=(128, 64, 32))
    raise ValueError(scale)


def init_params(rng, cfg):
    k = jax.random.split(rng, 5)
    p = {
        "user_gmf": common.normal(k[0], (cfg["n_users"], cfg["gmf_dim"]), scale=0.05),
        "item_gmf": common.normal(k[1], (cfg["n_items"], cfg["gmf_dim"]), scale=0.05),
        "user_mlp": common.normal(k[2], (cfg["n_users"], cfg["mlp_emb"]), scale=0.05),
        "item_mlp": common.normal(k[3], (cfg["n_items"], cfg["mlp_emb"]), scale=0.05),
    }
    dims = [2 * cfg["mlp_emb"], *cfg["mlp_hidden"]]
    p.update(common.mlp_params(k[4], dims, prefix="fc"))
    out_in = cfg["gmf_dim"] + cfg["mlp_hidden"][-1]
    p["out_w"] = common.glorot(jax.random.fold_in(rng, 99), (out_in, 1))
    p["out_b"] = common.zeros((1,))
    return p


def _logits(params, users, items, cfg):
    gmf = params["user_gmf"][users] * params["item_gmf"][items]
    mlp_in = jnp.concatenate(
        [params["user_mlp"][users], params["item_mlp"][items]], axis=-1
    )
    n_layers = len(cfg["mlp_hidden"])
    mlp = common.mlp_apply(params, mlp_in, n_layers, activation="relu",
                           final_activation="relu")
    feat = jnp.concatenate([gmf, mlp], axis=-1)
    out = common.dense(feat, params["out_w"], params["out_b"], "none")
    return out[:, 0]


def loss_fn(params, batch, cfg):
    users, items, labels = batch
    return common.bce_with_logits(_logits(params, users, items, cfg), labels)


def predict_fn(params, inputs, cfg):
    users, items = inputs
    return (jax.nn.sigmoid(_logits(params, users, items, cfg)),)


def batch_spec(cfg, b):
    return [
        jax.ShapeDtypeStruct((b,), jnp.int32),   # user ids
        jax.ShapeDtypeStruct((b,), jnp.int32),   # item ids
        jax.ShapeDtypeStruct((b,), jnp.float32), # implicit labels {0,1}
    ]


def predict_spec(cfg, b):
    return [
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    ]

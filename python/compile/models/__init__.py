"""Layer-2 model zoo (build-time; each model exports fwd_bwd + predict)."""

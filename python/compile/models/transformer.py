"""Decoder-only transformer LM — the mandated end-to-end training driver.

Pre-norm GPT-style blocks: LayerNorm (fused Pallas kernel) → causal MHA
(QKV/out projections through the Pallas GEMM) → LayerNorm → FFN (two
Pallas GEMMs, gelu≈tanh-free relu variant kept VJP-friendly). Scaled to
the 1-core testbed (the paper-scale 100M-param config is a dims change;
see EXPERIMENTS.md §E9 for the scaling note).
"""

import jax
import jax.numpy as jnp

from . import common


def config(scale="small"):
    if scale == "small":
        return dict(vocab=256, seq=32, d=64, heads=4, layers=2, ff=128)
    if scale == "e2e":  # the examples/train_transformer workload
        return dict(vocab=256, seq=64, d=128, heads=4, layers=4, ff=256)
    raise ValueError(scale)


def init_params(rng, cfg):
    d, ff, v = cfg["d"], cfg["ff"], cfg["vocab"]
    params = {
        "tok_emb": common.normal(rng, (v, d), scale=0.02),
        "pos_emb": common.normal(jax.random.fold_in(rng, 1), (cfg["seq"], d), scale=0.02),
    }
    for l in range(cfg["layers"]):
        k = jax.random.split(jax.random.fold_in(rng, 100 + l), 6)
        params[f"l{l}_ln1_g"] = jnp.ones((d,))
        params[f"l{l}_ln1_b"] = common.zeros((d,))
        params[f"l{l}_qkv_w"] = common.glorot(k[0], (d, 3 * d))
        params[f"l{l}_qkv_b"] = common.zeros((3 * d,))
        params[f"l{l}_proj_w"] = common.glorot(k[1], (d, d))
        params[f"l{l}_proj_b"] = common.zeros((d,))
        params[f"l{l}_ln2_g"] = jnp.ones((d,))
        params[f"l{l}_ln2_b"] = common.zeros((d,))
        params[f"l{l}_ff1_w"] = common.glorot(k[2], (d, ff))
        params[f"l{l}_ff1_b"] = common.zeros((ff,))
        params[f"l{l}_ff2_w"] = common.glorot(k[3], (ff, d))
        params[f"l{l}_ff2_b"] = common.zeros((d,))
    params["lnf_g"] = jnp.ones((d,))
    params["lnf_b"] = common.zeros((d,))
    return params


def _attention(x2d, params, l, cfg, bsz):
    d, h, t = cfg["d"], cfg["heads"], cfg["seq"]
    hd = d // h
    qkv = common.dense(x2d, params[f"l{l}_qkv_w"], params[f"l{l}_qkv_b"], "none")
    qkv = qkv.reshape(bsz, t, 3, h, hd).transpose(2, 0, 3, 1, 4)  # [3,B,h,T,hd]
    q, k, v = qkv[0], qkv[1], qkv[2]
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal[None, None], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bhsd->bhtd", probs, v)
    ctx2d = ctx.transpose(0, 2, 1, 3).reshape(bsz * t, d)
    return common.dense(ctx2d, params[f"l{l}_proj_w"], params[f"l{l}_proj_b"], "none")


def _logits(params, tokens, cfg):
    bsz, t = tokens.shape
    d = cfg["d"]
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :, :]
    x2d = x.reshape(bsz * t, d)
    for l in range(cfg["layers"]):
        a = common.layer_norm(x2d, params[f"l{l}_ln1_g"], params[f"l{l}_ln1_b"])
        x2d = x2d + _attention(a, params, l, cfg, bsz)
        f = common.layer_norm(x2d, params[f"l{l}_ln2_g"], params[f"l{l}_ln2_b"])
        f = common.dense(f, params[f"l{l}_ff1_w"], params[f"l{l}_ff1_b"], "relu")
        f = common.dense(f, params[f"l{l}_ff2_w"], params[f"l{l}_ff2_b"], "none")
        x2d = x2d + f
    x2d = common.layer_norm(x2d, params["lnf_g"], params["lnf_b"])
    logits2d = x2d @ params["tok_emb"].T  # weight-tied output head
    return logits2d.reshape(bsz, t, cfg["vocab"])


def loss_fn(params, batch, cfg):
    tokens, targets = batch
    logits = _logits(params, tokens, cfg)
    return common.softmax_xent(logits.reshape(-1, cfg["vocab"]), targets.reshape(-1))


def predict_fn(params, inputs, cfg):
    (tokens,) = inputs
    logits = _logits(params, tokens, cfg)
    return (jax.nn.log_softmax(logits, axis=-1),)


def batch_spec(cfg, b):
    t = cfg["seq"]
    return [
        jax.ShapeDtypeStruct((b, t), jnp.int32),
        jax.ShapeDtypeStruct((b, t), jnp.int32),
    ]


def predict_spec(cfg, b):
    return [jax.ShapeDtypeStruct((b, cfg["seq"]), jnp.int32)]

"""Shared L2 building blocks: Pallas-backed dense layer, LayerNorm, inits.

`dense` is the bridge between L2 (jax models) and L1 (Pallas kernels):
forward is the fused matmul+bias+activation kernel, and — because
`pallas_call` is not generically differentiable — backward is a custom VJP
whose three GEMMs (dx, dw, and the activation-gradient producer) also run
through the Pallas kernel, so the *entire* training hot path lowers to the
same tiled kernel.
"""

import functools

import jax
import jax.numpy as jnp

from ..kernels import matmul as mm
from ..kernels.layernorm import layernorm as _ln_kernel

# Activation derivatives expressible from the *output* y = act(z) — lets the
# VJP avoid stashing the pre-activation.
_ACT_GRAD_FROM_Y = {
    "none": lambda y: jnp.ones_like(y),
    "relu": lambda y: (y > 0).astype(y.dtype),
    "sigmoid": lambda y: y * (1.0 - y),
    "tanh": lambda y: 1.0 - jnp.square(y),
}


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation="none"):
    """act(x @ w + b) via the L1 Pallas kernel, differentiable.

    x: [M, K], w: [K, N], b: [N]. activation ∈ {none, relu, sigmoid, tanh}.
    """
    return mm.matmul_bias_act(x, w, b, activation=activation)


def _dense_fwd(x, w, b, activation):
    y = mm.matmul_bias_act(x, w, b, activation=activation)
    return y, (x, w, y)


def _dense_bwd(activation, res, dy):
    x, w, y = res
    dz = dy * _ACT_GRAD_FROM_Y[activation](y)
    zeros_k = jnp.zeros((w.shape[0],), dtype=x.dtype)
    zeros_n = jnp.zeros((w.shape[1],), dtype=x.dtype)
    # dx = dz @ w.T ; dw = x.T @ dz — both through the Pallas kernel.
    dx = mm.matmul_bias_act(dz, w.T, zeros_k, activation="none")
    dw = mm.matmul_bias_act(x.T, dz, zeros_n, activation="none")
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


@jax.custom_vjp
def layer_norm(x, gamma, beta):
    """LayerNorm over the last axis via the fused L1 kernel. x: [M, D]."""
    return _ln_kernel(x, gamma, beta)


def _ln_fwd(x, gamma, beta):
    return _ln_kernel(x, gamma, beta), (x, gamma)


def _ln_bwd(res, dy):
    x, gamma = res
    eps = 1e-5
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (xf - mean) * inv
    dyf = dy.astype(jnp.float32)
    dgamma = jnp.sum(dyf * xhat, axis=0)
    dbeta = jnp.sum(dyf, axis=0)
    dxhat = dyf * gamma
    d = x.shape[-1]
    dx = (
        dxhat
        - jnp.mean(dxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    ) * inv
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype), dbeta.astype(gamma.dtype)


layer_norm.defvjp(_ln_fwd, _ln_bwd)


# ---------------------------------------------------------------------------
# Initializers (mirroring BigDL's Torch-style defaults).


def glorot(rng, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(rng, shape, dtype, -lim, lim)


def normal(rng, shape, scale=0.01, dtype=jnp.float32):
    return scale * jax.random.normal(rng, shape, dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def mlp_params(rng, dims, prefix="fc"):
    """Dense stack params: dims = [in, h1, ..., out]."""
    params = {}
    keys = jax.random.split(rng, len(dims) - 1)
    for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"{prefix}{i}_w"] = glorot(keys[i], (d_in, d_out))
        params[f"{prefix}{i}_b"] = zeros((d_out,))
    return params


def mlp_apply(params, x, n_layers, activation="relu", final_activation="none",
              prefix="fc"):
    for i in range(n_layers):
        act = activation if i < n_layers - 1 else final_activation
        x = dense(x, params[f"{prefix}{i}_w"], params[f"{prefix}{i}_b"], act)
    return x


def conv2d(x, w, b, *, stride=1, padding="SAME", activation="none"):
    """2-D convolution as im2col + the Pallas matmul kernel.

    x: [B, C, H, W], w: [C*kh*kw, C_out], b: [C_out]. Patch extraction is an
    XLA op (differentiable); the GEMM — the FLOPs hot spot — runs through
    the L1 kernel in both forward and backward (dense's custom VJP).
    """
    bsz, c, h, _w = x.shape
    k2, c_out = w.shape
    k = int(round((k2 // c) ** 0.5))
    assert c * k * k == k2, f"kernel shape mismatch: {k2} vs C={c},k={k}"
    patches = jax.lax.conv_general_dilated_patches(
        x, (k, k), (stride, stride), padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [B, C*k*k, H', W']
    hp, wp = patches.shape[2], patches.shape[3]
    cols = patches.transpose(0, 2, 3, 1).reshape(bsz * hp * wp, k2)
    out = dense(cols, w, b, activation)
    return out.reshape(bsz, hp, wp, c_out).transpose(0, 3, 1, 2)


def conv_params(rng, c_in, c_out, k, prefix, params):
    params[f"{prefix}_w"] = glorot(rng, (c_in * k * k, c_out))
    params[f"{prefix}_b"] = zeros((c_out,))


def bce_with_logits(logits, labels):
    """Numerically-stable binary cross entropy (BigDL's BCECriterion)."""
    z = logits
    return jnp.mean(jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z))))


def softmax_xent(logits, labels):
    """Mean cross entropy with integer labels (ClassNLL + LogSoftMax)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)

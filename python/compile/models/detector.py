"""SSD-lite + DeepBit-lite — the §5.1 JD.com pipeline models (inference
only, "pre-trained in Caffe" in the paper; here initialized deterministic
and exported predict-only).

SSD-lite: single-scale anchor grid over a small conv backbone → per-anchor
(score, cx, cy, w, h). DeepBit-lite: conv backbone → 32-bit binary
descriptor (sigmoid output; the Rust pipeline binarizes at 0.5).
"""

import jax
import jax.numpy as jnp

from . import common


class _PredictOnly:
    """Duck-typed model module with predict-only exports."""

    def __init__(self, name, cfg_fn, init_fn, predict, spec):
        self.__name__ = name
        self._cfg = cfg_fn
        self._init = init_fn
        self._predict = predict
        self._spec = spec

    def config(self, scale):
        return self._cfg(scale)

    def init_params(self, rng, cfg):
        return self._init(rng, cfg)

    def predict_fn(self, params, inputs, cfg):
        return self._predict(params, inputs, cfg)

    def predict_spec(self, cfg, b):
        return self._spec(cfg, b)

    # Predict-only: no training entry.
    def batch_spec(self, cfg, b):  # pragma: no cover
        raise NotImplementedError

    def loss_fn(self, params, batch, cfg):  # pragma: no cover
        raise NotImplementedError


# ---- SSD-lite --------------------------------------------------------------

def _ssd_config(scale):
    # 32x32 input, 4x4 anchor grid (stride 8), 1 anchor per cell.
    return dict(channels=3, size=32, feat=16, grid=4)


def _ssd_init(rng, cfg):
    params = {}
    k = jax.random.split(rng, 3)
    common.conv_params(k[0], cfg["channels"], cfg["feat"], 3, "c1", params)
    common.conv_params(k[1], cfg["feat"], cfg["feat"], 3, "c2", params)
    # Head: per-cell 5 outputs (score + 4 box offsets).
    common.conv_params(k[2], cfg["feat"], 5, 1, "head", params)
    return params


def _ssd_predict(params, inputs, cfg):
    (images,) = inputs
    x = common.conv2d(images, params["c1_w"], params["c1_b"], activation="relu")
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 1, 4, 4), (1, 1, 4, 4), "VALID")
    x = common.conv2d(x, params["c2_w"], params["c2_b"], activation="relu")
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    out = common.conv2d(x, params["head_w"], params["head_b"])  # [B,5,g,g]
    b = out.shape[0]
    g = cfg["grid"]
    out = out.reshape(b, 5, g * g).transpose(0, 2, 1)  # [B, anchors, 5]
    scores = jax.nn.sigmoid(out[..., 0])
    boxes = jax.nn.sigmoid(out[..., 1:])  # normalized cx,cy,w,h
    return (scores, boxes)


def _ssd_spec(cfg, b):
    c, s = cfg["channels"], cfg["size"]
    return [jax.ShapeDtypeStruct((b, c, s, s), jnp.float32)]


SSD_LITE = _PredictOnly("ssd_lite", _ssd_config, _ssd_init, _ssd_predict, _ssd_spec)


# ---- DeepBit-lite ----------------------------------------------------------

def _db_config(scale):
    return dict(channels=3, size=16, feat=16, bits=32)


def _db_init(rng, cfg):
    params = {}
    k = jax.random.split(rng, 3)
    common.conv_params(k[0], cfg["channels"], cfg["feat"], 3, "c1", params)
    common.conv_params(k[1], cfg["feat"], cfg["feat"], 3, "c2", params)
    params["fc_w"] = common.glorot(k[2], (cfg["feat"], cfg["bits"]))
    params["fc_b"] = common.zeros((cfg["bits"],))
    return params


def _db_predict(params, inputs, cfg):
    (images,) = inputs
    x = common.conv2d(images, params["c1_w"], params["c1_b"], activation="relu")
    x = common.conv2d(x, params["c2_w"], params["c2_b"], activation="relu")
    x = jnp.mean(x, axis=(2, 3))  # [B, feat]
    bits = common.dense(x, params["fc_w"], params["fc_b"], "sigmoid")
    return (bits,)


def _db_spec(cfg, b):
    c, s = cfg["channels"], cfg["size"]
    return [jax.ShapeDtypeStruct((b, c, s, s), jnp.float32)]


DEEPBIT_LITE = _PredictOnly("deepbit_lite", _db_config, _db_init, _db_predict, _db_spec)

"""AOT exporter: lower every registered model to HLO text + metadata.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax≥0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model entry this writes into --out:
  <name>_fwdbwd.hlo.txt    (flat_params, *batch)  -> (loss, flat_grads)
  <name>_predict.hlo.txt   (flat_params, *inputs) -> (outputs...)
  <name>.meta.json         input/output specs, param layout, batch sizes
  <name>.params.bin        initial flat params, little-endian f32

Python runs ONCE at build time; the Rust binary is self-contained after
`make artifacts`.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc
from jax.flatten_util import ravel_pytree

from . import model as registry


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def _param_layout(params):
    """Flat offsets per leaf, in ravel_pytree order (sorted dict keys)."""
    leaves = jax.tree_util.tree_leaves_with_path(params)
    layout, off = [], 0
    for path, leaf in leaves:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        layout.append({
            "name": jax.tree_util.keystr(path),
            "offset": off,
            "size": size,
            "shape": list(leaf.shape),
        })
        off += size
    return layout, off


def export_entry(name: str, entry, out_dir: str) -> dict:
    mod, cfg = entry.module, entry.module.config(entry.scale)
    params = mod.init_params(jax.random.PRNGKey(42), entry.scale if False else cfg)
    flat, unravel = ravel_pytree(params)
    flat = flat.astype(jnp.float32)
    layout, total = _param_layout(params)
    assert total == flat.shape[0], f"{name}: layout {total} != flat {flat.shape[0]}"

    meta = {
        "name": name,
        "scale": entry.scale,
        "param_count": total,
        "param_layout": layout,
        "config": {k: (list(v) if isinstance(v, tuple) else v) for k, v in cfg.items()},
        "entries": {},
    }

    pspec = jax.ShapeDtypeStruct((total,), jnp.float32)

    if entry.train_batch > 0:
        bspec = mod.batch_spec(cfg, entry.train_batch)

        def fwd_bwd(flat_params, *batch):
            def loss_of(fp):
                return mod.loss_fn(unravel(fp), batch, cfg)

            loss, grads = jax.value_and_grad(loss_of)(flat_params)
            return loss, grads

        lowered = jax.jit(fwd_bwd).lower(pspec, *bspec)
        path = os.path.join(out_dir, f"{name}_fwdbwd.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        meta["entries"]["fwd_bwd"] = {
            "file": os.path.basename(path),
            "batch_size": entry.train_batch,
            "inputs": [_spec_json(pspec)] + [_spec_json(s) for s in bspec],
            "outputs": [
                {"shape": [], "dtype": "float32"},
                {"shape": [total], "dtype": "float32"},
            ],
        }

    if entry.predict_batch > 0:
        ispec = mod.predict_spec(cfg, entry.predict_batch)

        def predict(flat_params, *inputs):
            return mod.predict_fn(unravel(flat_params), inputs, cfg)

        lowered = jax.jit(predict).lower(pspec, *ispec)
        path = os.path.join(out_dir, f"{name}_predict.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        out_shapes = jax.eval_shape(predict, pspec, *ispec)
        meta["entries"]["predict"] = {
            "file": os.path.basename(path),
            "batch_size": entry.predict_batch,
            "inputs": [_spec_json(pspec)] + [_spec_json(s) for s in ispec],
            "outputs": [_spec_json(s) for s in out_shapes],
        }

    np.asarray(flat).astype("<f4").tofile(os.path.join(out_dir, f"{name}.params.bin"))
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of model names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = args.only or list(registry.ENTRIES)
    for name in names:
        entry = registry.ENTRIES[name]
        meta = export_entry(name, entry, args.out)
        sizes = {k: v["batch_size"] for k, v in meta["entries"].items()}
        print(f"[aot] {name}: params={meta['param_count']} entries={sizes}")
    # Build stamp consumed by the Makefile.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("\n".join(sorted(names)) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())

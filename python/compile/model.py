"""Layer-2 entry point: the registry of models AOT-exported to artifacts/.

Each entry names a model module (see models/), a config scale, and the
static batch sizes baked into the exported HLO. The Rust coordinator pads
partial minibatches up to these sizes (meta.json records them).

The per-model train batch here is the *per-replica* (per-Spark-task)
minibatch; BigDL's global batch = per-replica batch × #partitions.
"""

from dataclasses import dataclass
from typing import Any

from .models import ncf


@dataclass(frozen=True)
class Entry:
    module: Any
    scale: str
    train_batch: int
    predict_batch: int


# Registry; aot.py exports every entry (or a --only subset).
ENTRIES = {
    "ncf": Entry(ncf, "small", 128, 512),
}


def register(name: str, entry: Entry) -> None:
    ENTRIES[name] = entry


def _late_registrations() -> None:
    """Models added after the initial NCF bring-up; kept in one place so a
    broken model import fails loudly at export time, not import time."""
    from .models import inception_lite, transformer, convlstm, textclf, detector

    register("inception_lite", Entry(inception_lite, "small", 32, 64))
    register("transformer", Entry(transformer, "small", 8, 8))
    register("transformer_e2e", Entry(transformer, "e2e", 8, 8))
    register("convlstm", Entry(convlstm, "small", 4, 4))
    register("textclf", Entry(textclf, "small", 32, 128))
    register("ssd_lite", Entry(detector.SSD_LITE, "small", 0, 16))
    register("deepbit_lite", Entry(detector.DEEPBIT_LITE, "small", 0, 32))


try:
    _late_registrations()
except ImportError:
    # During incremental bring-up only NCF exists; aot --only ncf still works.
    pass

"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package has an exact (up to float tolerance) reference
here; python/tests/test_kernels.py sweeps shapes/dtypes/activations and
asserts allclose between kernel and oracle.
"""

import jax
import jax.numpy as jnp

_ACTIVATIONS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
}


def matmul_bias_act(x, w, b, *, activation: str = "none"):
    """Reference for kernels.matmul.matmul_bias_act."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    y = y + b.astype(jnp.float32)
    return _ACTIVATIONS[activation](y).astype(x.dtype)


def layernorm(x, gamma, beta, *, eps: float = 1e-5):
    """Reference for kernels.layernorm.layernorm."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(x.dtype)

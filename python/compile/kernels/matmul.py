"""L1 Pallas kernel: tiled matmul with fused bias + activation epilogue.

This is the MXU hot-spot of every model in the repo (NCF MLP towers,
transformer projections/FFN, text-classifier dense layers, im2col conv).

TPU mapping (see DESIGN.md §Hardware-Adaptation): BigDL's per-replica hot
spot is a cache-blocked MKL GEMM on Xeon; here the same insight is expressed
as a VMEM-tiled Pallas kernel targeting the MXU systolic array:

  * grid = (M/bm, N/bn, K/bk); the K axis is the innermost ("arbitrary")
    grid dimension so the f32 accumulator block stays resident in VMEM
    across the whole K loop (revisiting the same output block),
  * the bias add + activation run as a fused epilogue on the last K step,
    saving an HBM round-trip (the analogue of MKL-DNN post-ops),
  * block shapes default to MXU-friendly 128x128 (8x128 lane layout).

On this image Pallas MUST run with interpret=True (CPU PJRT cannot execute
Mosaic custom-calls); correctness is checked against kernels.ref, and TPU
efficiency is *estimated* from the BlockSpec footprint (see tools/vmem.py
and EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-friendly tile sizes. bm/bn match the 128x128 systolic array;
# bk=128 keeps x/w tiles at 64KiB each (f32) so tiles + accumulator fit
# comfortably in ~16MiB VMEM with room for double-buffering.
BM, BN, BK = 128, 128, 128

_ACTIVATIONS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
}


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j]; epilogue at k=nk-1.

    The output block is revisited for every k, so it doubles as the VMEM
    accumulator (avoids a scratch buffer; f32 accumulate as on the MXU).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(k == nk - 1)
    def _epilogue():
        act = _ACTIVATIONS[activation]
        o_ref[...] = act(o_ref[...] + b_ref[...]).astype(o_ref.dtype)


def _pad_to(x, multiples):
    pads = []
    for dim, m in zip(x.shape, multiples):
        rem = (-dim) % m
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "bk", "interpret")
)
def matmul_bias_act(
    x,
    w,
    b,
    *,
    activation: str = "none",
    bm: int = BM,
    bn: int = BN,
    bk: int = BK,
    interpret: bool = True,
):
    """act(x @ w + b) with a Pallas tiled kernel.

    x: [M, K], w: [K, N], b: [N] (broadcast over rows). Arbitrary M/K/N —
    inputs are zero-padded up to the tile grid and the result is sliced
    back (zero padding is exact for matmul; the epilogue runs on padded
    tiles but padded rows/cols are discarded).
    """
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    # Shrink blocks for small operands so tiny layers don't pay for padding.
    bm, bn, bk = min(bm, _ceil_mult(m)), min(bn, _ceil_mult(n)), min(bk, _ceil_mult(k))

    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    bp = _pad_to(b, (bn,))
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def _ceil_mult(dim: int, lane: int = 8) -> int:
    """Smallest lane-aligned block covering `dim` (≥8 keeps TPU lane layout)."""
    return max(lane, ((dim + lane - 1) // lane) * lane)


def vmem_bytes(bm: int = BM, bn: int = BN, bk: int = BK, dtype_bytes: int = 4,
               double_buffered: bool = True) -> int:
    """Static VMEM footprint estimate for a tile configuration.

    x tile + w tile (double-buffered input streams) + resident accumulator
    + bias tile. Used by tools/vmem.py for the §Perf roofline estimate.
    """
    streams = (bm * bk + bk * bn + bn) * dtype_bytes
    if double_buffered:
        streams *= 2
    acc = bm * bn * 4  # f32 accumulator
    return streams + acc


def mxu_utilization(m: int, n: int, k: int, bm: int = BM, bn: int = BN,
                    bk: int = BK) -> float:
    """Fraction of MXU tile work that is useful (non-padding) FLOPs."""
    gm, gn, gk = -(-m // bm), -(-n // bn), -(-k // bk)
    return (m * n * k) / float(gm * bm * gn * bn * gk * bk)

"""L1 Pallas kernel: fused LayerNorm (mean/var/normalize/scale/shift).

Row-blocked: each grid step loads a [bm, D] tile into VMEM, computes the
row statistics and writes the normalized tile — one HBM read + one write
per element instead of the ~4 passes a naive composition would take.
Used by the transformer LM blocks. interpret=True on this image.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128  # rows per tile


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def layernorm(x, gamma, beta, *, eps: float = 1e-5, bm: int = BM,
              interpret: bool = True):
    """LayerNorm over the last axis of x: [M, D] -> [M, D]."""
    m, d = x.shape
    bm = min(bm, max(8, ((m + 7) // 8) * 8))
    rem = (-m) % bm
    xp = jnp.pad(x, ((0, rem), (0, 0))) if rem else x
    grid = (xp.shape[0] // bm,)
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, gamma, beta)
    return out[:m]

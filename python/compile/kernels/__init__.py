"""Layer-1 Pallas kernels (build-time only; lowered into the model HLO).

NOTE: function re-exports deliberately avoid shadowing the submodules
(`kernels.layernorm` stays importable as a module).
"""

from . import layernorm, matmul, ref  # noqa: F401  (submodules)
from .matmul import matmul_bias_act, mxu_utilization, vmem_bytes  # noqa: F401

"""L1 kernel correctness: Pallas vs pure-jnp oracle (`kernels.ref`).

hypothesis is unavailable in this offline image, so the sweeps are explicit
parameterized grids over shapes (aligned / ragged / tiny / tall-skinny),
dtypes and activations — the same coverage intent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import compile.kernels.layernorm as ln
import compile.kernels.matmul as mm
from compile.kernels import ref

SHAPES = [
    (8, 8, 8),        # single tile
    (128, 128, 128),  # exactly one MXU tile
    (256, 128, 64),   # multi-tile M
    (50, 33, 20),     # ragged everything
    (1, 7, 1),        # degenerate
    (200, 1, 64),     # K=1
    (3, 500, 5),      # wide K
]

ACTS = ["none", "relu", "sigmoid", "tanh", "gelu"]


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("act", ACTS)
def test_matmul_matches_ref_f32(m, k, n, act):
    x = _rand((m, k), jnp.float32, m * 1000 + k)
    w = _rand((k, n), jnp.float32, k * 1000 + n)
    b = _rand((n,), jnp.float32, n)
    got = mm.matmul_bias_act(x, w, b, activation=act)
    want = ref.matmul_bias_act(x, w, b, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,k,n", [(32, 64, 16), (50, 33, 20)])
def test_matmul_bf16(m, k, n):
    x = _rand((m, k), jnp.bfloat16, 1)
    w = _rand((k, n), jnp.bfloat16, 2)
    b = _rand((n,), jnp.bfloat16, 3)
    got = mm.matmul_bias_act(x, w, b, activation="relu")
    want = ref.matmul_bias_act(x, w, b, activation="relu")
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=0.1, atol=0.1
    )


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (128, 128, 128), (8, 128, 64)])
def test_matmul_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the tiling."""
    x = _rand((96, 72), jnp.float32, 4)
    w = _rand((72, 40), jnp.float32, 5)
    b = _rand((40,), jnp.float32, 6)
    got = mm.matmul_bias_act(x, w, b, activation="tanh", bm=bm, bn=bn, bk=bk)
    want = ref.matmul_bias_act(x, w, b, activation="tanh")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_matmul_rejects_bad_activation():
    x = _rand((4, 4), jnp.float32, 7)
    with pytest.raises(ValueError):
        mm.matmul_bias_act(x, x, jnp.zeros(4), activation="swish")


@pytest.mark.parametrize("m,d", [(8, 16), (128, 64), (50, 33), (1, 8), (257, 128)])
def test_layernorm_matches_ref(m, d):
    x = _rand((m, d), jnp.float32, m * 37 + d)
    g = _rand((d,), jnp.float32, d) * 0.1 + 1.0
    b = _rand((d,), jnp.float32, d + 1)
    got = ln.layernorm(x, g, b)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_layernorm_statistics():
    x = _rand((64, 128), jnp.float32, 11)
    out = ln.layernorm(x, jnp.ones(128), jnp.zeros(128))
    mean = np.asarray(out).mean(axis=-1)
    std = np.asarray(out).std(axis=-1)
    np.testing.assert_allclose(mean, 0.0, atol=1e-4)
    np.testing.assert_allclose(std, 1.0, atol=1e-2)


def test_vmem_estimate_within_budget():
    """The default tile config must fit TPU VMEM (~16 MiB) with headroom."""
    bytes_ = mm.vmem_bytes()
    assert bytes_ < 8 * 1024 * 1024, f"default tiles need {bytes_} bytes"
    assert mm.mxu_utilization(128, 128, 128) == 1.0
    assert mm.mxu_utilization(130, 128, 128) < 0.6  # padding waste visible


def test_dense_custom_vjp_matches_jax_grad():
    """The Pallas-backed dense VJP must equal autodiff of the reference."""
    from compile.models import common

    x = _rand((10, 12), jnp.float32, 21)
    w = _rand((12, 8), jnp.float32, 22)
    b = _rand((8,), jnp.float32, 23)
    for act in ["none", "relu", "sigmoid", "tanh"]:
        def f_kernel(x, w, b):
            return jnp.sum(common.dense(x, w, b, act) ** 2)

        def f_ref(x, w, b):
            return jnp.sum(ref.matmul_bias_act(x, w, b, activation=act) ** 2)

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
        for a, c in zip(gk, gr):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(c), rtol=5e-4, atol=5e-4
            )


def test_layernorm_custom_vjp_matches_jax_grad():
    from compile.models import common

    x = _rand((6, 16), jnp.float32, 31)
    g = _rand((16,), jnp.float32, 32) * 0.1 + 1.0
    b = _rand((16,), jnp.float32, 33)

    def f_kernel(x, g, b):
        return jnp.sum(common.layer_norm(x, g, b) ** 3)

    def f_ref(x, g, b):
        return jnp.sum(ref.layernorm(x, g, b) ** 3)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, g, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, g, b)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-3, atol=1e-3)


def test_conv2d_matches_lax_conv():
    from compile.models import common

    rng = np.random.default_rng(44)
    x = jnp.asarray(rng.standard_normal((2, 3, 8, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3 * 3 * 3, 5)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(5), jnp.float32)
    got = common.conv2d(x, w, b)
    # Reference: lax.conv with OIHW kernel reshaped from our col-major W.
    w_oihw = w.T.reshape(5, 3, 3, 3)
    want = jax.lax.conv_general_dilated(
        x, w_oihw, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
    ) + b[None, :, None, None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

"""L2 model checks: loss finiteness + descent under SGD, gradient vs
numerical difference on tiny configs, predict output contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from compile.models import convlstm, detector, inception_lite, ncf, textclf, transformer

MODELS = {
    "ncf": ncf,
    "inception_lite": inception_lite,
    "transformer": transformer,
    "convlstm": convlstm,
    "textclf": textclf,
}


def tiny_batch(mod, cfg, b, seed=0):
    rng = np.random.default_rng(seed)
    batch = []
    for spec in mod.batch_spec(cfg, b):
        if spec.dtype == jnp.int32:
            hi = min(v for k, v in cfg.items()
                     if k in ("vocab", "n_users", "n_items", "classes") ) if any(
                k in cfg for k in ("vocab", "n_users", "n_items", "classes")) else 10
            batch.append(jnp.asarray(rng.integers(0, max(hi, 2), spec.shape), jnp.int32))
        else:
            batch.append(jnp.asarray(rng.standard_normal(spec.shape), jnp.float32))
    return tuple(batch)


@pytest.mark.parametrize("name", list(MODELS))
def test_loss_finite_and_grads_nonzero(name):
    mod = MODELS[name]
    cfg = mod.config("small")
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    batch = tiny_batch(mod, cfg, 4)
    loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(p, batch, cfg))(params)
    assert jnp.isfinite(loss), f"{name} loss {loss}"
    flat, _ = ravel_pytree(grads)
    assert jnp.all(jnp.isfinite(flat))
    nonzero = int(jnp.sum(flat != 0))
    # Embedding-table grads are legitimately sparse (only batch entities
    # receive gradient), so the bar is absolute, not proportional.
    assert nonzero > 500, f"{name}: only {nonzero}/{flat.size} grads nonzero"


@pytest.mark.parametrize("name", list(MODELS))
def test_sgd_descends(name):
    mod = MODELS[name]
    cfg = mod.config("small")
    params = mod.init_params(jax.random.PRNGKey(1), cfg)
    batch = tiny_batch(mod, cfg, 4, seed=1)
    flat, unravel = ravel_pytree(params)

    def loss_of(fp):
        return mod.loss_fn(unravel(fp), batch, cfg)

    l0 = float(loss_of(flat))
    g = jax.grad(loss_of)(flat)
    # Line-search a safe step: fixed-batch loss must drop.
    for lr in [1e-1, 1e-2, 1e-3]:
        l1 = float(loss_of(flat - lr * g))
        if l1 < l0:
            break
    assert l1 < l0, f"{name}: no descent direction found ({l0} -> {l1})"


def test_ncf_grad_matches_numerical():
    cfg = dict(n_users=12, n_items=8, gmf_dim=3, mlp_emb=4, mlp_hidden=(6, 4))
    params = ncf.init_params(jax.random.PRNGKey(2), cfg)
    users = jnp.array([0, 3, 5], jnp.int32)
    items = jnp.array([1, 2, 7], jnp.int32)
    labels = jnp.array([1.0, 0.0, 1.0])
    flat, unravel = ravel_pytree(params)

    def loss_of(fp):
        return ncf.loss_fn(unravel(fp), (users, items, labels), cfg)

    g = jax.grad(loss_of)(flat)
    rng = np.random.default_rng(3)
    eps = 1e-3
    for idx in rng.choice(flat.size, 12, replace=False):
        e = jnp.zeros_like(flat).at[idx].set(eps)
        num = (loss_of(flat + e) - loss_of(flat - e)) / (2 * eps)
        assert abs(float(num) - float(g[idx])) < 5e-3, (
            f"param {idx}: numerical {num} vs autodiff {g[idx]}"
        )


def test_transformer_beats_uniform_on_fixed_batch():
    cfg = transformer.config("small")
    params = transformer.init_params(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg["vocab"], (4, cfg["seq"])), jnp.int32)
    batch = (toks, toks)  # predict-own-input: overfittable
    flat, unravel = ravel_pytree(params)

    def loss_of(fp):
        return transformer.loss_fn(unravel(fp), batch, cfg)

    uniform = float(np.log(cfg["vocab"]))
    l0 = float(loss_of(flat))
    assert abs(l0 - uniform) < 1.0, f"init loss {l0} should be near ln V {uniform}"
    g = jax.grad(loss_of)
    w = flat
    for _ in range(10):
        w = w - 0.5 * g(w)
    assert float(loss_of(w)) < l0 - 0.3, "transformer failed to overfit a fixed batch"


@pytest.mark.parametrize("name", list(MODELS))
def test_predict_contract(name):
    mod = MODELS[name]
    cfg = mod.config("small")
    params = mod.init_params(jax.random.PRNGKey(6), cfg)
    b = 3
    rng = np.random.default_rng(7)
    inputs = []
    for spec in mod.predict_spec(cfg, b):
        if spec.dtype == jnp.int32:
            inputs.append(jnp.asarray(rng.integers(0, 5, spec.shape), jnp.int32))
        else:
            inputs.append(jnp.asarray(rng.standard_normal(spec.shape), jnp.float32))
    outs = mod.predict_fn(params, tuple(inputs), cfg)
    assert isinstance(outs, tuple)
    for o in outs:
        assert o.shape[0] == b, f"{name}: output not batch-major: {o.shape}"
        assert bool(jnp.all(jnp.isfinite(o)))


def test_ssd_lite_outputs_scores_and_boxes():
    cfg = detector.SSD_LITE.config("small")
    params = detector.SSD_LITE.init_params(jax.random.PRNGKey(8), cfg)
    imgs = jnp.zeros((2, 3, 32, 32))
    scores, boxes = detector.SSD_LITE.predict_fn(params, (imgs,), cfg)
    assert scores.shape == (2, 16)
    assert boxes.shape == (2, 16, 4)
    assert bool(jnp.all((scores >= 0) & (scores <= 1)))
    assert bool(jnp.all((boxes >= 0) & (boxes <= 1)))


def test_deepbit_lite_descriptor_range():
    cfg = detector.DEEPBIT_LITE.config("small")
    params = detector.DEEPBIT_LITE.init_params(jax.random.PRNGKey(9), cfg)
    imgs = jnp.ones((2, 3, 16, 16))
    (bits,) = detector.DEEPBIT_LITE.predict_fn(params, (imgs,), cfg)
    assert bits.shape == (2, 32)
    assert bool(jnp.all((bits >= 0) & (bits <= 1)))

"""AOT contract tests: meta.json layout consistency, params.bin length,
HLO text loadability (via jax's own parser is unavailable — we validate
the textual header), and numerical equivalence of the exported fwd_bwd
with the in-python loss/grad."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from compile import aot
from compile import model as registry

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "ncf.meta.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
@pytest.mark.parametrize("name", sorted(registry.ENTRIES))
def test_meta_layout_tiles_param_space(name):
    with open(os.path.join(ARTIFACTS, f"{name}.meta.json")) as f:
        meta = json.load(f)
    off = 0
    for leaf in meta["param_layout"]:
        assert leaf["offset"] == off, f"{name}: gap before {leaf['name']}"
        off += leaf["size"]
        want = int(np.prod(leaf["shape"])) if leaf["shape"] else 1
        assert leaf["size"] == want
    assert off == meta["param_count"]
    params = np.fromfile(os.path.join(ARTIFACTS, f"{name}.params.bin"), dtype="<f4")
    assert params.size == meta["param_count"]
    assert np.isfinite(params).all()


@needs_artifacts
@pytest.mark.parametrize("name", sorted(registry.ENTRIES))
def test_hlo_files_exist_and_look_like_hlo(name):
    with open(os.path.join(ARTIFACTS, f"{name}.meta.json")) as f:
        meta = json.load(f)
    for entry in meta["entries"].values():
        path = os.path.join(ARTIFACTS, entry["file"])
        with open(path) as f:
            text = f.read(4000)
        assert "HloModule" in text, f"{path} does not look like HLO text"
        assert entry["batch_size"] > 0
        for spec in entry["inputs"]:
            assert spec["dtype"] in ("float32", "int32")


def test_exported_fwd_bwd_matches_python(tmp_path):
    """Golden test: export NCF into a temp dir, then check the flat-grad
    function built by aot equals value_and_grad of the model directly."""
    entry = registry.ENTRIES["ncf"]
    mod, cfg = entry.module, entry.module.config(entry.scale)
    params = mod.init_params(jax.random.PRNGKey(42), cfg)
    flat, unravel = ravel_pytree(params)

    b = 8
    users = jnp.arange(b, dtype=jnp.int32)
    items = jnp.arange(b, dtype=jnp.int32) % 4
    labels = (jnp.arange(b) % 2).astype(jnp.float32)

    def fwd_bwd(fp, *batch):
        def loss_of(q):
            return mod.loss_fn(unravel(q), batch, cfg)
        return jax.value_and_grad(loss_of)(fp)

    loss1, grads1 = fwd_bwd(flat, users, items, labels)
    loss2, grads2 = jax.value_and_grad(
        lambda q: mod.loss_fn(unravel(q), (users, items, labels), cfg)
    )(flat)
    assert float(loss1) == pytest.approx(float(loss2))
    np.testing.assert_allclose(np.asarray(grads1), np.asarray(grads2))


def test_to_hlo_text_roundtrip_smoke():
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text


@needs_artifacts
def test_registry_covers_all_artifacts():
    on_disk = {
        f.split(".meta.json")[0]
        for f in os.listdir(ARTIFACTS)
        if f.endswith(".meta.json")
    }
    assert on_disk == set(registry.ENTRIES), (
        f"artifacts {on_disk} != registry {set(registry.ENTRIES)}"
    )
